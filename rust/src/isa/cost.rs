//! Cost model: t_c (logic time), t_d (data-fetch time), offload check.
//!
//! Paper §4.1: the dispatch engine computes `t_c = t_i · N` from the
//! accelerator's known per-instruction time and offloads only if
//! `t_c ≤ η · t_d`, with η = m/n the accelerator's logic:memory pipeline
//! ratio (§4.2, Property 2). §6.2/Fig. 10 calibrate the components:
//! logic ≈ 10 ns for WebService's ~2-3 effective instructions at 250 MHz
//! (4 ns/instr) and the memory pipeline path (TCAM 22 + memory controller
//! 110 + interconnect 47 ns) ≈ 179 ns per aggregated load.

use super::op::Op;
use super::program::Program;

/// The prototype's offload threshold η = m/n = 3/4 (paper §4.2: 3 logic
/// pipelines / 4 memory pipelines). Single source for the dispatch
/// engine default and the per-structure `offloadable` assertions in
/// `ds/` — a new scenario's iterator must clear `t_c ≤ DEFAULT_ETA·t_d`
/// or it silently falls back to CPU-side execution.
pub const DEFAULT_ETA: f64 = 0.75;

/// Timing parameters of one PULSE accelerator (FPGA prototype defaults).
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Per-instruction logic time (250 MHz pipeline => 4 ns).
    pub t_instr_ns: f64,
    /// Fixed memory-pipeline overhead per iteration: TCAM translation +
    /// memory-controller setup + interconnect (22 + 110 + 47 ns, Fig 10).
    pub t_mem_fixed_ns: f64,
    /// Per-word (8 B) DRAM random-burst time (matches
    /// `LatencyModel::accel_word_ns`; calibrated to Table 3 ratios).
    pub t_mem_word_ns: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            t_instr_ns: 4.0,
            t_mem_fixed_ns: 22.0 + 110.0 + 47.0,
            t_mem_word_ns: 3.2,
        }
    }
}

/// Static per-iteration cost estimate of a program.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IterCost {
    /// Worst-case dynamic instructions per iteration (forward-jump rule
    /// makes program length the exact upper bound).
    pub n_instrs: usize,
    /// Logic time per iteration, ns.
    pub t_c_ns: f64,
    /// Data-fetch time per iteration, ns.
    pub t_d_ns: f64,
}

impl IterCost {
    /// The compute-to-memory ratio the paper tabulates per workload
    /// (Table 3: 0.06 for hash table, 0.63 B+Tree lookups, 0.71 BTrDB).
    pub fn ratio(&self) -> f64 {
        self.t_c_ns / self.t_d_ns
    }
}

impl CostModel {
    /// Analyze a program. `n_instrs` counts non-LOAD/STORE work (the
    /// logic pipeline executes everything except the aggregated fetch,
    /// but window LD/ST hit workspace registers and still occupy logic
    /// slots — we count them at full instruction cost, matching the prototype
    /// where workspace access is single-cycle).
    pub fn cost(&self, p: &Program) -> IterCost {
        let n = p.instrs.len();
        let t_c = self.t_instr_ns * n as f64;
        let words = p.load_words.max(1) as f64;
        // Write-back doubles the streamed words for dirty windows.
        let wb = if p.writes_data { 2.0 } else { 1.0 };
        let t_d = self.t_mem_fixed_ns + self.t_mem_word_ns * words * wb;
        IterCost { n_instrs: n, t_c_ns: t_c, t_d_ns: t_d }
    }

    /// Offload decision: `t_c ≤ η · t_d` (paper §4.1).
    pub fn offloadable(&self, p: &Program, eta: f64) -> bool {
        let c = self.cost(p);
        c.t_c_ns <= eta * c.t_d_ns
    }

    /// Count of ALU-class (non-memory, non-control) instructions —
    /// diagnostic used to report Table 3 style ratios.
    pub fn alu_instrs(p: &Program) -> usize {
        p.instrs
            .iter()
            .filter(|i| {
                !i.op.touches_data()
                    && !i.op.is_jump()
                    && !i.op.is_terminal()
                    && i.op != Op::Nop
            })
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::asm::Asm;

    fn list_like() -> Program {
        let mut a = Asm::new();
        let stop = a.label();
        a.ldd(1, 2); // next ptr
        a.movi(2, 0);
        a.jeq(1, 2, stop);
        a.mov(0, 1);
        a.next();
        a.bind(stop);
        a.ret();
        a.finish(3).unwrap()
    }

    #[test]
    fn memory_bound_program_is_offloadable() {
        let m = CostModel::default();
        let p = list_like();
        let c = m.cost(&p);
        assert!(c.ratio() < 0.75, "ratio {}", c.ratio());
        assert!(m.offloadable(&p, 0.75));
    }

    #[test]
    fn compute_heavy_program_is_rejected() {
        let m = CostModel::default();
        let mut a = Asm::new();
        for _ in 0..30 {
            a.mul(1, 1, 1);
            a.add(2, 2, 1);
        }
        a.ret();
        let p = a.finish(1).unwrap();
        assert!(!m.offloadable(&p, 0.75));
        assert!(m.cost(&p).ratio() > 1.0);
    }

    #[test]
    fn writeback_increases_t_d() {
        let m = CostModel::default();
        let mut a = Asm::new();
        a.ldd(1, 0);
        a.ret();
        let read_only = a.finish(32).unwrap();
        let mut a = Asm::new();
        a.ldd(1, 0);
        a.std_(1, 1);
        a.ret();
        let writes = a.finish(32).unwrap();
        assert!(m.cost(&writes).t_d_ns > m.cost(&read_only).t_d_ns);
    }

    #[test]
    fn ratio_matches_table3_order_of_magnitude() {
        // Hash-table-like chain walk: few instructions, one small load —
        // paper reports t_c/t_d = 0.06 for WebService.
        let m = CostModel::default();
        let c = m.cost(&list_like());
        assert!(c.ratio() > 0.01 && c.ratio() < 0.5, "{}", c.ratio());
    }

    #[test]
    fn alu_count_excludes_control_and_memory() {
        let p = list_like();
        // movi + mov are ALU-class; ldd/jeq/next/ret are not.
        assert_eq!(CostModel::alu_instrs(&p), 2);
    }
}
