//! Abstract-interpretation analyzer: the dataflow extension of
//! [`verify`] (paper §4.1).
//!
//! `verify` bounds *structure* (forward jumps, static offsets, terminal
//! tails); this module bounds *behavior*. A single forward pass — sound
//! and complete as a fixpoint because verified programs have forward-only
//! control flow, so every predecessor of a pc has a smaller pc and no
//! widening is needed — runs four analyses at once:
//!
//! 1. **Interval analysis** over registers and scratchpad words, with
//!    branch refinement: proves computed window indices in-bounds (the
//!    radix trie's `slot = children + 8·byte` with `byte ∈ [0,255]`) and
//!    divisors nonzero (the graph k-hop `modu` lowering's guard).
//! 2. **Initialization analysis** over the scratchpad: reads of words no
//!    prior instruction wrote and the host did not declare as seeded
//!    (the `sp_inputs` mask) flag `ReadBeforeWrite`.
//! 3. **Trap-freedom**: `Analysis::trap_free` holds iff no reachable
//!    trap source survives — explicit TRAP, feasible jump past the end,
//!    unproven divisor, unproven dynamic window index.
//! 4. **Write-effect inference**: `Analysis::writes_dram` is true iff a
//!    reachable data-window store may execute (contrast
//!    `Program::writes_data`, a flat opcode scan that counts dead code).
//!
//! Severity calibration: a diagnostic is `Deny` only when the defect is
//! certain on some reachable path (provably-zero divisor, provably
//! out-of-bounds index); possible-but-unproven defects are `Warn`
//! (divisor that may be zero, undeclared scratchpad read) or silent but
//! reflected in `trap_free` (an index the analysis simply cannot bound —
//! data-dependent traversals like skip-list level picks are legitimate).
//! Progress analysis over `repeat_while` stage chains builds on the
//! per-program facts here; see `rack::request::Op::lint`.

#![deny(clippy::redundant_clone)]

use super::op::{Instr, Op};
use super::program::Program;
use super::verify::{verify, VerifyError};
use super::{DATA_WORDS, NREG, SP_WORDS};

/// `sp_inputs` mask declaring every scratchpad word host-seeded. The
/// right default for wire-registered programs: the REQUEST frame ships
/// the full 256 B scratchpad, so any word may legitimately be read.
pub const SP_INPUTS_ALL: u32 = u32::MAX;

// ---------------------------------------------------------------------
// Abstract domain: signed intervals + a path-derived nonzero flag.
// ---------------------------------------------------------------------

/// Abstract value of one 64-bit register or scratchpad word: a closed
/// signed interval `[lo, hi]`, plus a `nonzero` flag for path conditions
/// (`x != 0`) that an interval spanning zero cannot express.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AbsVal {
    pub lo: i64,
    pub hi: i64,
    pub nonzero: bool,
}

impl AbsVal {
    pub const TOP: AbsVal =
        AbsVal { lo: i64::MIN, hi: i64::MAX, nonzero: false };

    pub fn exact(k: i64) -> AbsVal {
        AbsVal { lo: k, hi: k, nonzero: k != 0 }
    }

    pub fn range(lo: i64, hi: i64) -> AbsVal {
        debug_assert!(lo <= hi);
        AbsVal { lo, hi, nonzero: lo > 0 || hi < 0 }
    }

    pub fn is_const(&self) -> bool {
        self.lo == self.hi
    }

    pub fn proves_nonzero(&self) -> bool {
        self.nonzero || self.lo > 0 || self.hi < 0
    }

    fn join(self, o: AbsVal) -> AbsVal {
        AbsVal {
            lo: self.lo.min(o.lo),
            hi: self.hi.max(o.hi),
            nonzero: self.nonzero && o.nonzero,
        }
    }

    /// Greatest lower bound; `None` when the intersection is empty (an
    /// infeasible path condition).
    fn meet(self, o: AbsVal) -> Option<AbsVal> {
        let lo = self.lo.max(o.lo);
        let hi = self.hi.min(o.hi);
        if lo > hi {
            return None;
        }
        AbsVal { lo, hi, nonzero: self.nonzero || o.nonzero }.normalize()
    }

    /// Tighten endpoints against the nonzero flag; `None` if the value
    /// is contradictory (nonzero yet exactly `[0,0]`).
    fn normalize(mut self) -> Option<AbsVal> {
        if self.nonzero {
            if self.lo == 0 && self.hi == 0 {
                return None;
            }
            if self.lo == 0 {
                self.lo = 1;
            }
            if self.hi == 0 {
                self.hi = -1;
            }
        }
        Some(self)
    }

    /// The same value under an established "is nonzero" path condition
    /// (the caller has ruled out the exactly-zero case).
    fn assume_nonzero(mut self) -> AbsVal {
        self.nonzero = true;
        self.normalize().unwrap_or(AbsVal {
            lo: 1,
            hi: i64::MAX,
            nonzero: true,
        })
    }
}

// ---------------------------------------------------------------------
// Transfer functions, pinned to `interp::logic_pass` semantics: exact
// (wrapping) folds when both operands are constants, checked interval
// arithmetic otherwise (any overflow at an interval bound widens to
// TOP, which always contains the wrapped runtime value).
// ---------------------------------------------------------------------

fn tr_add(x: AbsVal, y: AbsVal) -> AbsVal {
    if x.is_const() && y.is_const() {
        return AbsVal::exact(x.lo.wrapping_add(y.lo));
    }
    match (x.lo.checked_add(y.lo), x.hi.checked_add(y.hi)) {
        (Some(lo), Some(hi)) => AbsVal::range(lo, hi),
        _ => AbsVal::TOP,
    }
}

fn tr_sub(x: AbsVal, y: AbsVal) -> AbsVal {
    if x.is_const() && y.is_const() {
        return AbsVal::exact(x.lo.wrapping_sub(y.lo));
    }
    match (x.lo.checked_sub(y.hi), x.hi.checked_sub(y.lo)) {
        (Some(lo), Some(hi)) => AbsVal::range(lo, hi),
        _ => AbsVal::TOP,
    }
}

fn tr_mul(x: AbsVal, y: AbsVal) -> AbsVal {
    if x.is_const() && y.is_const() {
        return AbsVal::exact(x.lo.wrapping_mul(y.lo));
    }
    // Exact products over a box peak at the corners; if every corner is
    // representable, no interior product wraps either.
    let corners = [
        x.lo.checked_mul(y.lo),
        x.lo.checked_mul(y.hi),
        x.hi.checked_mul(y.lo),
        x.hi.checked_mul(y.hi),
    ];
    let mut lo = i64::MAX;
    let mut hi = i64::MIN;
    for c in corners {
        match c {
            Some(v) => {
                lo = lo.min(v);
                hi = hi.max(v);
            }
            None => return AbsVal::TOP,
        }
    }
    AbsVal::range(lo, hi)
}

/// Divisor proven nonzero by the caller (the trap edge is split off).
fn tr_div(x: AbsVal, y: AbsVal) -> AbsVal {
    if x.is_const() && y.is_const() && y.lo != 0 {
        return AbsVal::exact(x.lo.wrapping_div(y.lo));
    }
    AbsVal::TOP
}

fn tr_and(x: AbsVal, y: AbsVal) -> AbsVal {
    if x.is_const() && y.is_const() {
        return AbsVal::exact(x.lo & y.lo);
    }
    // Non-negative & anything non-negative stays within [0, min-hi];
    // with one non-negative operand the result is bounded by it.
    match (x.lo >= 0, y.lo >= 0) {
        (true, true) => AbsVal::range(0, x.hi.min(y.hi)),
        (true, false) => AbsVal::range(0, x.hi),
        (false, true) => AbsVal::range(0, y.hi),
        (false, false) => AbsVal::TOP,
    }
}

fn tr_or(x: AbsVal, y: AbsVal) -> AbsVal {
    if x.is_const() && y.is_const() {
        return AbsVal::exact(x.lo | y.lo);
    }
    AbsVal::TOP
}

fn tr_xor(x: AbsVal, y: AbsVal) -> AbsVal {
    if x.is_const() && y.is_const() {
        return AbsVal::exact(x.lo ^ y.lo);
    }
    AbsVal::TOP
}

fn tr_not(x: AbsVal) -> AbsVal {
    // !v == -1 - v, exactly; the endpoints can never overflow.
    AbsVal::range((-1i64).wrapping_sub(x.hi), (-1i64).wrapping_sub(x.lo))
}

fn tr_shl(x: AbsVal, imm: i64) -> AbsVal {
    let k = (imm & 63) as u32;
    if x.is_const() {
        return AbsVal::exact(x.lo.wrapping_shl(k));
    }
    if k == 0 {
        return x;
    }
    if x.lo >= 0 && x.hi <= (i64::MAX >> k) {
        // whole interval shifts without wrapping; monotone for x >= 0
        AbsVal::range(x.lo << k, x.hi << k)
    } else {
        AbsVal::TOP
    }
}

fn tr_shr(x: AbsVal, imm: i64) -> AbsVal {
    let k = (imm & 63) as u32;
    if k == 0 {
        // logical shift by 0 is the identity even for negative values
        return x;
    }
    if x.lo >= 0 {
        // logical == arithmetic for non-negative values; monotone
        AbsVal::range(x.lo >> k, x.hi >> k)
    } else {
        AbsVal::range(0, (u64::MAX >> k) as i64)
    }
}

// ---------------------------------------------------------------------
// Branch refinement.
// ---------------------------------------------------------------------

#[derive(Clone, Copy)]
enum Rel {
    Eq,
    Ne,
    Lt,
    Le,
}

/// The relation `rel(x, y)` that HOLDS on the given edge of a
/// conditional jump comparing `(r[a], r[b])`; `swap` means
/// `(x, y) = (r[b], r[a])`.
fn rel_of(op: Op, taken: bool) -> (Rel, bool) {
    match (op, taken) {
        (Op::Jeq, true) | (Op::Jne, false) => (Rel::Eq, false),
        (Op::Jeq, false) | (Op::Jne, true) => (Rel::Ne, false),
        (Op::Jlt, true) | (Op::Jge, false) => (Rel::Lt, false),
        (Op::Jlt, false) | (Op::Jge, true) => (Rel::Le, true),
        (Op::Jle, true) | (Op::Jgt, false) => (Rel::Le, false),
        (Op::Jle, false) | (Op::Jgt, true) => (Rel::Lt, true),
        _ => unreachable!("rel_of on non-conditional op"),
    }
}

/// Exclude `k` from `v`'s endpoints; `None` if `v` is exactly `k`.
fn trim_ne(v: AbsVal, k: i64) -> Option<AbsVal> {
    let mut v = v;
    if v.is_const() && v.lo == k {
        return None;
    }
    if v.lo == k {
        v.lo = k + 1; // hi > k, so k < i64::MAX
    }
    if v.hi == k {
        v.hi = k - 1; // lo < k, so k > i64::MIN
    }
    if k == 0 {
        v.nonzero = true;
    }
    v.normalize()
}

// ---------------------------------------------------------------------
// Per-pc abstract state.
// ---------------------------------------------------------------------

#[derive(Clone)]
struct State {
    regs: [AbsVal; NREG],
    sp: [AbsVal; SP_WORDS],
    /// Bit i set: sp[i] definitely written on every path here, or
    /// declared host-seeded via `sp_inputs`.
    init: u32,
    /// A dynamic sp store with unproven index ran: any word may have
    /// been written (suppresses ReadBeforeWrite from here on).
    dyn_write: bool,
}

impl State {
    /// Registers are TOP at entry, not zero: within one traversal the
    /// workspace persists across iterations, so a later pass observes
    /// whatever the previous pass left behind.
    fn entry(sp_inputs: u32) -> State {
        State {
            regs: [AbsVal::TOP; NREG],
            sp: [AbsVal::TOP; SP_WORDS],
            init: sp_inputs,
            dyn_write: false,
        }
    }

    fn join_into(&mut self, o: &State) {
        for (d, s) in self.regs.iter_mut().zip(&o.regs) {
            *d = d.join(*s);
        }
        for (d, s) in self.sp.iter_mut().zip(&o.sp) {
            *d = d.join(*s);
        }
        self.init &= o.init;
        self.dyn_write |= o.dyn_write;
    }
}

/// Refine `st` along one edge of a conditional jump; `None` means the
/// edge is infeasible.
fn refine(st: &State, op: Op, taken: bool, a: u8, b: u8) -> Option<State> {
    let (rel, swap) = rel_of(op, taken);
    let (ra, rb) = if swap {
        (b as usize, a as usize)
    } else {
        (a as usize, b as usize)
    };
    let x = st.regs[ra];
    let y = st.regs[rb];
    match rel {
        Rel::Eq => {
            if ra == rb {
                return Some(st.clone());
            }
            let m = x.meet(y)?;
            let mut st = st.clone();
            st.regs[ra] = m;
            st.regs[rb] = m;
            Some(st)
        }
        Rel::Ne => {
            if ra == rb {
                return None;
            }
            if x.is_const() && y.is_const() && x.lo == y.lo {
                return None;
            }
            let mut st = st.clone();
            if y.is_const() {
                st.regs[ra] = trim_ne(x, y.lo)?;
            }
            if x.is_const() {
                st.regs[rb] = trim_ne(y, x.lo)?;
            }
            Some(st)
        }
        Rel::Lt => {
            // x < y
            if ra == rb {
                return None;
            }
            let xh = y.hi.checked_sub(1)?; // y.hi == MIN: nothing below
            let nx = AbsVal { hi: x.hi.min(xh), ..x };
            if nx.lo > nx.hi {
                return None;
            }
            let yl = x.lo.checked_add(1)?; // x.lo == MAX: nothing above
            let ny = AbsVal { lo: y.lo.max(yl), ..y };
            if ny.lo > ny.hi {
                return None;
            }
            let mut st = st.clone();
            st.regs[ra] = nx.normalize()?;
            st.regs[rb] = ny.normalize()?;
            Some(st)
        }
        Rel::Le => {
            // x <= y
            if ra == rb {
                return Some(st.clone());
            }
            let nx = AbsVal { hi: x.hi.min(y.hi), ..x };
            if nx.lo > nx.hi {
                return None;
            }
            let ny = AbsVal { lo: y.lo.max(x.lo), ..y };
            if ny.lo > ny.hi {
                return None;
            }
            let mut st = st.clone();
            st.regs[ra] = nx.normalize()?;
            st.regs[rb] = ny.normalize()?;
            Some(st)
        }
    }
}

// ---------------------------------------------------------------------
// Diagnostics.
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// Certain defect on a reachable path — reject at admission.
    Deny,
    /// Possible defect the analysis cannot rule out — report, admit.
    Warn,
}

impl std::fmt::Display for Severity {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Severity::Deny => write!(f, "deny"),
            Severity::Warn => write!(f, "warn"),
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DiagKind {
    /// Structural verification failure (the analyzer runs `verify`
    /// first; dataflow needs a well-formed program).
    Verify(VerifyError),
    PossibleDivByZero { divisor: u8 },
    ReadBeforeWrite { word: u32 },
    ComputedOffsetOob { window: &'static str, lo: i64, hi: i64 },
    NoProgressRepeat { stage: usize, addr_word: u32, guard_word: u32 },
}

impl DiagKind {
    pub fn name(&self) -> &'static str {
        match self {
            DiagKind::Verify(_) => "Verify",
            DiagKind::PossibleDivByZero { .. } => "PossibleDivByZero",
            DiagKind::ReadBeforeWrite { .. } => "ReadBeforeWrite",
            DiagKind::ComputedOffsetOob { .. } => "ComputedOffsetOob",
            DiagKind::NoProgressRepeat { .. } => "NoProgressRepeat",
        }
    }
}

impl std::fmt::Display for DiagKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DiagKind::Verify(e) => write!(f, "{e}"),
            DiagKind::PossibleDivByZero { divisor } => {
                write!(f, "divisor r{divisor} is not provably nonzero")
            }
            DiagKind::ReadBeforeWrite { word } => write!(
                f,
                "scratchpad word {word} read before any write \
                 (not declared in sp_inputs)"
            ),
            DiagKind::ComputedOffsetOob { window, lo, hi } => write!(
                f,
                "computed {window}-window index provably out of bounds \
                 ({lo}..={hi})"
            ),
            DiagKind::NoProgressRepeat { stage, addr_word, guard_word } => {
                write!(
                    f,
                    "stage {stage} repeats while sp[{addr_word}] != 0 && \
                     sp[{guard_word}] > 0 but no path updates either word"
                )
            }
        }
    }
}

/// One structured diagnostic, carrying the disassembly of the offending
/// instruction so every consumer (compile error, wire ERROR frame,
/// `pulse lint`) renders identically.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diag {
    pub pc: usize,
    pub severity: Severity,
    pub kind: DiagKind,
    pub rendered_instr: String,
}

impl std::fmt::Display for Diag {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} @pc {} [{}]: {} | {}",
            self.severity,
            self.pc,
            self.kind.name(),
            self.kind,
            self.rendered_instr
        )
    }
}

impl Diag {
    /// Wrap a structural `VerifyError` in the shared diagnostic
    /// rendering, pointing at the offending instruction when the error
    /// names a pc.
    pub fn from_verify(p: &Program, e: VerifyError) -> Diag {
        let pc = match &e {
            VerifyError::BadRegister { pc, .. }
            | VerifyError::StaticOffsetOob { pc, .. }
            | VerifyError::NonForwardJump { pc, .. } => *pc,
            VerifyError::NonTerminalTail => p.instrs.len().saturating_sub(1),
            _ => 0,
        };
        Diag {
            pc,
            severity: Severity::Deny,
            rendered_instr: render_instr(p, pc),
            kind: DiagKind::Verify(e),
        }
    }
}

/// Disassemble one instruction for diagnostics.
pub fn render_instr(p: &Program, pc: usize) -> String {
    match p.instrs.get(pc) {
        Some(i) => i.to_string(),
        None => "<no instruction>".to_string(),
    }
}

/// The one shared formatter for verify failures: severity, pc, message,
/// and the disassembled offending instruction.
pub fn render_verify_error(p: &Program, e: &VerifyError) -> String {
    Diag::from_verify(p, e.clone()).to_string()
}

// ---------------------------------------------------------------------
// Analysis result + driver.
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Analysis {
    /// All diagnostics, in program order (progress diagnostics are
    /// appended by `Op::lint`, which sees the whole stage chain).
    pub diags: Vec<Diag>,
    /// A reachable data-window store may execute.
    pub writes_dram: bool,
    /// Bit i: some reachable instruction may write sp[i] (static SPS,
    /// or a dynamic SPSX whose index interval covers i).
    pub sp_writes: u32,
    /// A reachable dynamic sp store whose index could not be bounded —
    /// any word may be written.
    pub sp_dyn_write: bool,
    /// No reachable trap source survives the analysis.
    pub trap_free: bool,
    /// No reachable NEXT: the program finishes in a single iteration.
    pub returns_only: bool,
    reg_in: Vec<Option<[AbsVal; NREG]>>,
}

impl Analysis {
    pub fn has_deny(&self) -> bool {
        self.diags.iter().any(|d| d.severity == Severity::Deny)
    }

    /// Joined interval of `reg` on entry to `pc`; `None` if `pc` is
    /// unreachable (or out of range).
    pub fn interval_before(&self, pc: usize, reg: u8) -> Option<(i64, i64)> {
        let regs = self.reg_in.get(pc)?.as_ref()?;
        let v = regs[reg as usize];
        Some((v.lo, v.hi))
    }
}

fn mk(p: &Program, pc: usize, severity: Severity, kind: DiagKind) -> Diag {
    Diag { pc, severity, kind, rendered_instr: render_instr(p, pc) }
}

fn flow(states: &mut [Option<State>], target: usize, st: State) {
    match &mut states[target] {
        Some(cur) => cur.join_into(&st),
        slot @ None => *slot = Some(st),
    }
}

/// Analyze `p` under the host-seeded scratchpad declaration
/// `sp_inputs`. Runs `verify` first: a structurally invalid program
/// yields a single Deny diagnostic and no dataflow facts.
pub fn analyze(p: &Program, sp_inputs: u32) -> Analysis {
    let mut out = Analysis {
        diags: Vec::new(),
        writes_dram: false,
        sp_writes: 0,
        sp_dyn_write: false,
        trap_free: true,
        returns_only: true,
        reg_in: vec![None; p.instrs.len()],
    };
    if let Err(e) = verify(p) {
        out.diags.push(Diag::from_verify(p, e));
        // analysis did not run: stay conservative
        out.writes_dram = p.writes_data;
        out.trap_free = false;
        out.returns_only = false;
        return out;
    }
    let n = p.instrs.len();
    let mut states: Vec<Option<State>> = vec![None; n];
    states[0] = Some(State::entry(sp_inputs));
    for pc in 0..n {
        let Some(mut st) = states[pc].take() else {
            continue; // unreachable pc
        };
        out.reg_in[pc] = Some(st.regs);
        let Instr { op, a, b, c, imm } = p.instrs[pc];
        let (ai, bi, ci) = (a as usize, b as usize, c as usize);
        match op {
            Op::Nop => flow(&mut states, pc + 1, st),
            Op::Ldd => {
                st.regs[ai] = AbsVal::TOP;
                flow(&mut states, pc + 1, st);
            }
            Op::Std => {
                out.writes_dram = true;
                flow(&mut states, pc + 1, st);
            }
            Op::Spl => {
                let w = imm as usize;
                if !st.dyn_write && st.init & (1 << w) == 0 {
                    out.diags.push(mk(
                        p,
                        pc,
                        Severity::Warn,
                        DiagKind::ReadBeforeWrite { word: w as u32 },
                    ));
                    // one warning per word per path
                    st.init |= 1 << w;
                }
                st.regs[ai] = st.sp[w];
                flow(&mut states, pc + 1, st);
            }
            Op::Sps => {
                let w = imm as usize;
                st.sp[w] = st.regs[ai];
                st.init |= 1 << w;
                out.sp_writes |= 1 << w;
                flow(&mut states, pc + 1, st);
            }
            Op::Ldx | Op::Stx | Op::Splx | Op::Spsx => {
                let data = op.touches_data();
                let window = if data { "data" } else { "sp" };
                let words =
                    if data { DATA_WORDS as i64 } else { SP_WORDS as i64 };
                let base = st.regs[bi];
                let idx = tr_add(base, AbsVal::exact(imm));
                if idx.hi < 0 || idx.lo >= words {
                    // every execution reaching here traps
                    out.diags.push(mk(
                        p,
                        pc,
                        Severity::Deny,
                        DiagKind::ComputedOffsetOob {
                            window,
                            lo: idx.lo,
                            hi: idx.hi,
                        },
                    ));
                    out.trap_free = false;
                    continue; // no successor
                }
                let proven = idx.lo >= 0 && idx.hi < words;
                if !proven {
                    out.trap_free = false;
                    // Surviving the runtime check implies base+imm landed
                    // in-window; refine the base register when no value
                    // in its interval can wrap in the add.
                    if base.lo.checked_add(imm).is_some()
                        && base.hi.checked_add(imm).is_some()
                    {
                        let lo = 0i64.checked_sub(imm);
                        let hi = (words - 1).checked_sub(imm);
                        if let (Some(lo), Some(hi)) = (lo, hi) {
                            if let Some(r) =
                                st.regs[bi].meet(AbsVal::range(lo, hi))
                            {
                                st.regs[bi] = r;
                            }
                        }
                    }
                }
                match op {
                    Op::Ldx => st.regs[ai] = AbsVal::TOP,
                    Op::Stx => out.writes_dram = true,
                    Op::Splx => {
                        if proven && idx.is_const() {
                            let w = idx.lo as usize;
                            if !st.dyn_write && st.init & (1 << w) == 0 {
                                out.diags.push(mk(
                                    p,
                                    pc,
                                    Severity::Warn,
                                    DiagKind::ReadBeforeWrite {
                                        word: w as u32,
                                    },
                                ));
                                st.init |= 1 << w;
                            }
                            st.regs[ai] = st.sp[w];
                        } else {
                            st.regs[ai] = AbsVal::TOP;
                        }
                    }
                    Op::Spsx => {
                        if proven {
                            let v = st.regs[ai];
                            let (lo, hi) = (idx.lo as usize, idx.hi as usize);
                            for w in lo..=hi {
                                if idx.is_const() {
                                    st.sp[w] = v;
                                    st.init |= 1 << w;
                                } else {
                                    // may-write: weak update
                                    st.sp[w] = st.sp[w].join(v);
                                }
                                out.sp_writes |= 1 << w;
                            }
                        } else {
                            st.dyn_write = true;
                            out.sp_dyn_write = true;
                            for w in st.sp.iter_mut() {
                                *w = AbsVal::TOP;
                            }
                        }
                    }
                    _ => unreachable!(),
                }
                flow(&mut states, pc + 1, st);
            }
            Op::Mov => {
                st.regs[ai] = st.regs[bi];
                flow(&mut states, pc + 1, st);
            }
            Op::Movi => {
                st.regs[ai] = AbsVal::exact(imm);
                flow(&mut states, pc + 1, st);
            }
            Op::Add => {
                st.regs[ai] = tr_add(st.regs[bi], st.regs[ci]);
                flow(&mut states, pc + 1, st);
            }
            Op::Sub => {
                st.regs[ai] = tr_sub(st.regs[bi], st.regs[ci]);
                flow(&mut states, pc + 1, st);
            }
            Op::Mul => {
                st.regs[ai] = tr_mul(st.regs[bi], st.regs[ci]);
                flow(&mut states, pc + 1, st);
            }
            Op::Div => {
                let d = st.regs[ci];
                if d.proves_nonzero() {
                    // statically safe
                } else if d.is_const() && d.lo == 0 {
                    out.diags.push(mk(
                        p,
                        pc,
                        Severity::Deny,
                        DiagKind::PossibleDivByZero { divisor: c },
                    ));
                    out.trap_free = false;
                    continue; // provably traps — no successor
                } else {
                    out.diags.push(mk(
                        p,
                        pc,
                        Severity::Warn,
                        DiagKind::PossibleDivByZero { divisor: c },
                    ));
                    out.trap_free = false;
                }
                // the surviving path has a nonzero divisor
                st.regs[ci] = st.regs[ci].assume_nonzero();
                st.regs[ai] = tr_div(st.regs[bi], st.regs[ci]);
                flow(&mut states, pc + 1, st);
            }
            Op::And => {
                st.regs[ai] = tr_and(st.regs[bi], st.regs[ci]);
                flow(&mut states, pc + 1, st);
            }
            Op::Or => {
                st.regs[ai] = tr_or(st.regs[bi], st.regs[ci]);
                flow(&mut states, pc + 1, st);
            }
            Op::Xor => {
                st.regs[ai] = tr_xor(st.regs[bi], st.regs[ci]);
                flow(&mut states, pc + 1, st);
            }
            Op::Not => {
                st.regs[ai] = tr_not(st.regs[bi]);
                flow(&mut states, pc + 1, st);
            }
            Op::Shl => {
                st.regs[ai] = tr_shl(st.regs[bi], imm);
                flow(&mut states, pc + 1, st);
            }
            Op::Shr => {
                st.regs[ai] = tr_shr(st.regs[bi], imm);
                flow(&mut states, pc + 1, st);
            }
            Op::Addi => {
                st.regs[ai] = tr_add(st.regs[bi], AbsVal::exact(imm));
                flow(&mut states, pc + 1, st);
            }
            Op::Jmp => {
                let t = imm as usize;
                if t < n {
                    flow(&mut states, t, st);
                } else {
                    // verify allows target == n; jumping there traps
                    out.trap_free = false;
                }
            }
            Op::Jeq | Op::Jne | Op::Jlt | Op::Jle | Op::Jgt | Op::Jge => {
                let t = imm as usize;
                if let Some(taken) = refine(&st, op, true, a, b) {
                    if t < n {
                        flow(&mut states, t, taken);
                    } else {
                        out.trap_free = false;
                    }
                }
                if let Some(fall) = refine(&st, op, false, a, b) {
                    flow(&mut states, pc + 1, fall);
                }
            }
            Op::Next => out.returns_only = false,
            Op::Ret => {}
            Op::Trap => out.trap_free = false,
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Asm;
    use std::sync::Arc;

    fn prog(f: impl FnOnce(&mut Asm)) -> Program {
        let mut a = Asm::new();
        f(&mut a);
        a.finish(32).unwrap()
    }

    #[test]
    fn provable_div_by_zero_is_denied() {
        let p = prog(|a| {
            a.movi(1, 5);
            a.movi(2, 0);
            a.div(3, 1, 2);
            a.ret();
        });
        let an = analyze(&p, SP_INPUTS_ALL);
        assert!(!an.trap_free);
        assert!(an.has_deny());
        assert_eq!(an.diags.len(), 1);
        let d = &an.diags[0];
        assert_eq!(d.pc, 2);
        assert_eq!(d.severity, Severity::Deny);
        assert_eq!(d.kind, DiagKind::PossibleDivByZero { divisor: 2 });
        assert!(d.rendered_instr.contains("Div"), "{}", d.rendered_instr);
    }

    #[test]
    fn possible_div_by_zero_warns_once_then_refines() {
        let p = prog(|a| {
            a.spl(1, 0);
            a.spl(2, 1);
            a.div(3, 1, 2);
            // the surviving path has r2 != 0: no second warning
            a.div(4, 1, 2);
            a.ret();
        });
        let an = analyze(&p, SP_INPUTS_ALL);
        assert_eq!(an.diags.len(), 1, "{:?}", an.diags);
        assert_eq!(an.diags[0].severity, Severity::Warn);
        assert_eq!(
            an.diags[0].kind,
            DiagKind::PossibleDivByZero { divisor: 2 }
        );
        assert!(!an.trap_free);
        assert!(!an.has_deny());
    }

    #[test]
    fn proven_nonzero_divisor_is_clean() {
        let p = prog(|a| {
            a.spl(1, 0);
            a.movi(2, 7);
            a.div(3, 1, 2);
            a.ret();
        });
        let an = analyze(&p, SP_INPUTS_ALL);
        assert!(an.diags.is_empty(), "{:?}", an.diags);
        assert!(an.trap_free);
    }

    #[test]
    fn read_before_write_flags_undeclared_word() {
        let p = prog(|a| {
            a.spl(1, 3);
            a.ret();
        });
        let an = analyze(&p, 0);
        assert_eq!(an.diags.len(), 1);
        assert_eq!(an.diags[0].severity, Severity::Warn);
        assert_eq!(an.diags[0].kind, DiagKind::ReadBeforeWrite { word: 3 });
        // declared as host-seeded: clean
        let an = analyze(&p, 1 << 3);
        assert!(an.diags.is_empty(), "{:?}", an.diags);
        // written first: clean without any declaration
        let p = prog(|a| {
            a.movi(1, 9);
            a.sps(1, 3);
            a.spl(2, 3);
            a.ret();
        });
        let an = analyze(&p, 0);
        assert!(an.diags.is_empty(), "{:?}", an.diags);
        assert_eq!(an.sp_writes, 1 << 3);
    }

    #[test]
    fn computed_offset_provably_oob_is_denied() {
        for k in [40i64, -1] {
            let p = prog(|a| {
                a.movi(1, k);
                a.ldx(2, 1, 0);
                a.ret();
            });
            let an = analyze(&p, SP_INPUTS_ALL);
            assert!(an.has_deny(), "k={k}");
            assert!(!an.trap_free);
            let d = &an.diags[0];
            assert_eq!(d.pc, 1);
            assert_eq!(
                d.kind,
                DiagKind::ComputedOffsetOob { window: "data", lo: k, hi: k }
            );
            assert!(d.rendered_instr.contains("Ldx"));
        }
    }

    #[test]
    fn computed_offset_proved_in_bounds_is_clean() {
        let p = prog(|a| {
            a.movi(1, 3);
            a.ldx(2, 1, 4); // data[7]
            a.ret();
        });
        let an = analyze(&p, SP_INPUTS_ALL);
        assert!(an.diags.is_empty(), "{:?}", an.diags);
        assert!(an.trap_free);
    }

    #[test]
    fn unknown_offset_is_silent_but_not_trap_free() {
        let p = prog(|a| {
            a.spl(1, 0);
            a.ldx(2, 1, 0);
            a.ret();
        });
        let an = analyze(&p, SP_INPUTS_ALL);
        assert!(an.diags.is_empty(), "{:?}", an.diags);
        assert!(!an.trap_free);
    }

    #[test]
    fn branch_refinement_proves_dynamic_bounds() {
        // guard an unknown index into [0, 32) by explicit branches; the
        // guarded load must be *proved* safe, keeping trap_free
        let p = prog(|a| {
            a.spl(1, 0);
            a.movi(2, 0);
            a.movi(3, 32);
            let skip = a.label();
            a.jlt(1, 2, skip); // idx < 0  -> skip
            a.jge(1, 3, skip); // idx >= 32 -> skip
            a.ldx(4, 1, 0);
            a.bind(skip);
            a.ret();
        });
        let an = analyze(&p, SP_INPUTS_ALL);
        assert!(an.diags.is_empty(), "{:?}", an.diags);
        assert!(an.trap_free, "guarded dynamic load must be proved safe");
        // entering the load, the index is pinned to [0, 31]
        let ldx_pc = p
            .instrs
            .iter()
            .position(|i| i.op == Op::Ldx)
            .unwrap();
        assert_eq!(an.interval_before(ldx_pc, 1), Some((0, 31)));
    }

    #[test]
    fn verify_failure_renders_offending_instruction() {
        let p = Program::new(vec![Instr::new(Op::Add, 1, 2, 3, 0)], 1);
        let an = analyze(&p, SP_INPUTS_ALL);
        assert!(an.has_deny());
        assert_eq!(an.diags.len(), 1);
        assert!(matches!(
            an.diags[0].kind,
            DiagKind::Verify(VerifyError::NonTerminalTail)
        ));
        assert!(an.diags[0].rendered_instr.contains("Add"));
        // the standalone formatter produces the same line
        let msg = render_verify_error(&p, &VerifyError::NonTerminalTail);
        assert_eq!(msg, an.diags[0].to_string());
        assert!(msg.contains("deny"));
    }

    #[test]
    fn writes_dram_is_reachability_aware() {
        // flat scan says "writes"; the dead store never executes
        let p = prog(|a| {
            let over = a.label();
            a.jmp(over);
            a.std_(1, 0);
            a.bind(over);
            a.ret();
        });
        assert!(p.writes_data);
        let an = analyze(&p, SP_INPUTS_ALL);
        assert!(!an.writes_dram);
        assert!(an.trap_free);

        let p = prog(|a| {
            a.movi(1, 7);
            a.std_(1, 0);
            a.ret();
        });
        let an = analyze(&p, SP_INPUTS_ALL);
        assert!(an.writes_dram);
    }

    #[test]
    fn explicit_trap_and_next_update_flags() {
        let p = prog(|a| {
            a.trap();
        });
        let an = analyze(&p, SP_INPUTS_ALL);
        assert!(!an.trap_free);
        assert!(an.diags.is_empty(), "explicit TRAP is legal, not a lint");

        let p = prog(|a| {
            a.movi(0, 0x1000);
            a.next();
        });
        let an = analyze(&p, SP_INPUTS_ALL);
        assert!(!an.returns_only);
        assert!(an.trap_free);
    }

    #[test]
    fn radix_trie_computed_offset_is_proved_in_bounds() {
        let it = crate::ds::radixtrie::lookup_iter();
        let an = analyze(&it.program, it.sp_inputs);
        assert!(an.diags.is_empty(), "{:?}", an.diags);
        let instrs = &it.program.instrs;
        // slot = children + (byte << 3), byte = rem >> 56
        let (shl_pc, shl) = instrs
            .iter()
            .enumerate()
            .find(|(_, i)| i.op == Op::Shl && i.imm == 3)
            .expect("slot-offset shl");
        assert_eq!(
            an.interval_before(shl_pc, shl.b),
            Some((0, 255)),
            "byte from the 56-bit logical shift"
        );
        let (add_pc, _) = instrs
            .iter()
            .enumerate()
            .skip(shl_pc + 1)
            .find(|(_, i)| i.op == Op::Add && (i.b == shl.a || i.c == shl.a))
            .expect("slot add");
        assert_eq!(
            an.interval_before(add_pc, shl.a),
            Some((0, 2040)),
            "slot offset proved in [0, 8*255]"
        );
    }

    #[test]
    fn graph_khop_is_clean_via_nonzero_refinement() {
        // the modu lowering divides by the vertex degree, which is only
        // safe because the deg == 0 path returns before the DIV
        let it = crate::ds::graph::khop_iter();
        let an = analyze(&it.program, it.sp_inputs);
        assert!(an.diags.is_empty(), "{:?}", an.diags);
        assert!(!an.trap_free, "explicit corrupt-adjacency TRAP remains");
        assert!(!an.writes_dram);
    }

    #[test]
    fn all_builtin_programs_analyze_clean() {
        for (name, it) in crate::ds::builtin_iters() {
            let an = analyze(&it.program, it.sp_inputs);
            assert!(
                an.diags.is_empty(),
                "{name}: {:?}",
                an.diags
            );
            assert!(!an.has_deny(), "{name}");
        }
    }

    #[test]
    fn no_progress_repeat_is_denied_with_escapes() {
        use crate::compiler::CompiledIter;
        use crate::isa::SP_WORDS;

        let read_only = Arc::new(CompiledIter::new(prog(|a| {
            a.spl(1, 0);
            a.ret();
        })));
        let mut op = crate::rack::Op::new(
            read_only.clone(),
            0x1000,
            [0i64; SP_WORDS],
        );
        op.stages[0].repeat_while = Some((1, 2));
        let diags = op.lint();
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].severity, Severity::Deny);
        assert_eq!(
            diags[0].kind,
            DiagKind::NoProgressRepeat {
                stage: 0,
                addr_word: 1,
                guard_word: 2
            }
        );

        // escape 1: an sp_override pins the predicate off each round
        let mut op2 = crate::rack::Op::new(
            read_only,
            0x1000,
            [0i64; SP_WORDS],
        );
        op2.stages[0].repeat_while = Some((1, 2));
        op2.stages[0].sp_overrides = vec![(2, 0)];
        assert!(op2.lint().is_empty(), "{:?}", op2.lint());

        // escape 2: the program writes a predicate word
        let writer = Arc::new(CompiledIter::new(prog(|a| {
            a.movi(1, 7);
            a.sps(1, 1);
            a.ret();
        })));
        let mut op3 =
            crate::rack::Op::new(writer, 0x1000, [0i64; SP_WORDS]);
        op3.stages[0].repeat_while = Some((1, 2));
        assert!(op3.lint().is_empty(), "{:?}", op3.lint());
    }

    #[test]
    fn scan_op_chains_pass_progress_lint() {
        // the two real repeat_while users must keep passing Op::lint
        let sk = crate::ds::skiplist::scan_iter();
        let an = analyze(&sk.program, sk.sp_inputs);
        assert!(
            an.sp_writes & (1 << 1) != 0,
            "skiplist scan updates its continuation word"
        );
        let bp = crate::ds::bplustree::scan_iter();
        let an = analyze(&bp.program, bp.sp_inputs);
        assert!(an.sp_writes & (1 << 1) != 0 || an.sp_dyn_write);
    }
}
