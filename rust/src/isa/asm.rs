//! Assembler: an ergonomic builder for PULSE programs with labels.
//!
//! Data-structure iterator programs (and the compiler's lowering pass)
//! build code through this API; it resolves forward labels and runs the
//! verifier on `finish()`.

use super::op::{Instr, Op};
use super::program::Program;
use super::verify::{verify, VerifyError};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Label(usize);

#[derive(Debug, Default)]
pub struct Asm {
    instrs: Vec<Instr>,
    /// label -> resolved pc (None until `bind`).
    labels: Vec<Option<usize>>,
    /// (instr index, label) fixups for forward references.
    fixups: Vec<(usize, Label)>,
}

impl Asm {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn here(&self) -> usize {
        self.instrs.len()
    }

    /// Create an unbound label (forward reference).
    pub fn label(&mut self) -> Label {
        self.labels.push(None);
        Label(self.labels.len() - 1)
    }

    /// Bind a label to the current position.
    pub fn bind(&mut self, l: Label) {
        assert!(self.labels[l.0].is_none(), "label bound twice");
        self.labels[l.0] = Some(self.instrs.len());
    }

    fn push(&mut self, op: Op, a: u8, b: u8, c: u8, imm: i64) -> &mut Self {
        self.instrs.push(Instr::new(op, a, b, c, imm));
        self
    }

    fn push_jump(&mut self, op: Op, a: u8, b: u8, l: Label) -> &mut Self {
        self.fixups.push((self.instrs.len(), l));
        self.instrs.push(Instr::new(op, a, b, 0, 0));
        self
    }

    // -- memory ------------------------------------------------------------
    pub fn ldd(&mut self, dst: u8, off: i64) -> &mut Self {
        self.push(Op::Ldd, dst, 0, 0, off)
    }
    pub fn ldx(&mut self, dst: u8, base: u8, off: i64) -> &mut Self {
        self.push(Op::Ldx, dst, base, 0, off)
    }
    pub fn std_(&mut self, src: u8, off: i64) -> &mut Self {
        self.push(Op::Std, src, 0, 0, off)
    }
    pub fn stx(&mut self, src: u8, base: u8, off: i64) -> &mut Self {
        self.push(Op::Stx, src, base, 0, off)
    }
    pub fn spl(&mut self, dst: u8, off: i64) -> &mut Self {
        self.push(Op::Spl, dst, 0, 0, off)
    }
    pub fn splx(&mut self, dst: u8, base: u8, off: i64) -> &mut Self {
        self.push(Op::Splx, dst, base, 0, off)
    }
    pub fn sps(&mut self, src: u8, off: i64) -> &mut Self {
        self.push(Op::Sps, src, 0, 0, off)
    }
    pub fn spsx(&mut self, src: u8, base: u8, off: i64) -> &mut Self {
        self.push(Op::Spsx, src, base, 0, off)
    }

    // -- moves / ALU ---------------------------------------------------------
    pub fn mov(&mut self, dst: u8, src: u8) -> &mut Self {
        self.push(Op::Mov, dst, src, 0, 0)
    }
    pub fn movi(&mut self, dst: u8, imm: i64) -> &mut Self {
        self.push(Op::Movi, dst, 0, 0, imm)
    }
    pub fn add(&mut self, dst: u8, x: u8, y: u8) -> &mut Self {
        self.push(Op::Add, dst, x, y, 0)
    }
    pub fn sub(&mut self, dst: u8, x: u8, y: u8) -> &mut Self {
        self.push(Op::Sub, dst, x, y, 0)
    }
    pub fn mul(&mut self, dst: u8, x: u8, y: u8) -> &mut Self {
        self.push(Op::Mul, dst, x, y, 0)
    }
    pub fn div(&mut self, dst: u8, x: u8, y: u8) -> &mut Self {
        self.push(Op::Div, dst, x, y, 0)
    }
    pub fn and(&mut self, dst: u8, x: u8, y: u8) -> &mut Self {
        self.push(Op::And, dst, x, y, 0)
    }
    pub fn or(&mut self, dst: u8, x: u8, y: u8) -> &mut Self {
        self.push(Op::Or, dst, x, y, 0)
    }
    pub fn xor(&mut self, dst: u8, x: u8, y: u8) -> &mut Self {
        self.push(Op::Xor, dst, x, y, 0)
    }
    pub fn not(&mut self, dst: u8, src: u8) -> &mut Self {
        self.push(Op::Not, dst, src, 0, 0)
    }
    pub fn shl(&mut self, dst: u8, src: u8, sh: i64) -> &mut Self {
        self.push(Op::Shl, dst, src, 0, sh)
    }
    pub fn shr(&mut self, dst: u8, src: u8, sh: i64) -> &mut Self {
        self.push(Op::Shr, dst, src, 0, sh)
    }
    pub fn addi(&mut self, dst: u8, src: u8, imm: i64) -> &mut Self {
        self.push(Op::Addi, dst, src, 0, imm)
    }

    // -- control -----------------------------------------------------------
    pub fn jeq(&mut self, x: u8, y: u8, l: Label) -> &mut Self {
        self.push_jump(Op::Jeq, x, y, l)
    }
    pub fn jne(&mut self, x: u8, y: u8, l: Label) -> &mut Self {
        self.push_jump(Op::Jne, x, y, l)
    }
    pub fn jlt(&mut self, x: u8, y: u8, l: Label) -> &mut Self {
        self.push_jump(Op::Jlt, x, y, l)
    }
    pub fn jle(&mut self, x: u8, y: u8, l: Label) -> &mut Self {
        self.push_jump(Op::Jle, x, y, l)
    }
    pub fn jgt(&mut self, x: u8, y: u8, l: Label) -> &mut Self {
        self.push_jump(Op::Jgt, x, y, l)
    }
    pub fn jge(&mut self, x: u8, y: u8, l: Label) -> &mut Self {
        self.push_jump(Op::Jge, x, y, l)
    }
    pub fn jmp(&mut self, l: Label) -> &mut Self {
        self.push_jump(Op::Jmp, 0, 0, l)
    }
    pub fn next(&mut self) -> &mut Self {
        self.push(Op::Next, 0, 0, 0, 0)
    }
    pub fn ret(&mut self) -> &mut Self {
        self.push(Op::Ret, 0, 0, 0, 0)
    }
    pub fn trap(&mut self) -> &mut Self {
        self.push(Op::Trap, 0, 0, 0, 0)
    }

    /// Resolve labels, build, verify.
    pub fn finish(mut self, load_words: u8) -> Result<Program, VerifyError> {
        for (idx, l) in std::mem::take(&mut self.fixups) {
            let target = self.labels[l.0]
                .unwrap_or_else(|| panic!("label {l:?} never bound"));
            self.instrs[idx].imm = target as i64;
        }
        let p = Program::new(self.instrs, load_words);
        verify(&p)?;
        Ok(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_label_resolution() {
        let mut a = Asm::new();
        let found = a.label();
        let done = a.label();
        a.spl(1, 0);
        a.ldd(2, 0);
        a.jeq(1, 2, found);
        a.movi(3, 0);
        a.jmp(done);
        a.bind(found);
        a.movi(3, 1);
        a.bind(done);
        a.sps(3, 1);
        a.ret();
        let p = a.finish(3).unwrap();
        assert_eq!(p.instrs[2].imm, 5); // jeq -> bind(found)
        assert_eq!(p.instrs[4].imm, 6); // jmp -> bind(done)
    }

    #[test]
    fn finish_runs_verifier() {
        let mut a = Asm::new();
        a.movi(1, 5);
        // no terminal:
        let err = a.finish(1).unwrap_err();
        assert_eq!(err, VerifyError::NonTerminalTail);
    }

    #[test]
    #[should_panic(expected = "never bound")]
    fn unbound_label_panics() {
        let mut a = Asm::new();
        let l = a.label();
        a.jmp(l);
        a.ret();
        let _ = a.finish(1);
    }

    #[test]
    fn backward_label_rejected_by_verifier() {
        let mut a = Asm::new();
        let back = a.label();
        a.bind(back);
        a.movi(1, 0);
        a.jmp(back);
        a.ret();
        assert!(a.finish(1).is_err());
    }
}
