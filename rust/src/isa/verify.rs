//! Program verifier (paper §4.1): forward-only jumps, bounded length,
//! in-window static offsets, register bounds, terminal tail.
//!
//! The forward-jump rule is what bounds per-iteration execution — any
//! verified program executes at most `n_instrs` dynamic steps, which both
//! the accelerator's cost model (t_c) and the lock-step XLA engine rely
//! on. Mirrors `python/compile/kernels/isa.py::verify`.

use super::op::{Instr, Op};
use super::program::Program;
use super::{DATA_WORDS, MAX_INSTRS, NREG, SP_WORDS};

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum VerifyError {
    Empty,
    TooLong { n: usize },
    BadRegister { pc: usize, reg: u8 },
    StaticOffsetOob { pc: usize, imm: i64, window: usize },
    NonForwardJump { pc: usize, target: i64 },
    NonTerminalTail,
    LoadWordsOutOfRange { load_words: u8 },
}

impl std::fmt::Display for VerifyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            VerifyError::Empty => write!(f, "empty program"),
            VerifyError::TooLong { n } => {
                write!(f, "program too long: {n} > {MAX_INSTRS}")
            }
            VerifyError::BadRegister { pc, reg } => {
                write!(f, "pc={pc}: register {reg} out of range")
            }
            VerifyError::StaticOffsetOob { pc, imm, window } => {
                write!(f, "pc={pc}: static offset {imm} outside window {window}")
            }
            VerifyError::NonForwardJump { pc, target } => {
                write!(f, "pc={pc}: jump target {target} not strictly forward")
            }
            VerifyError::NonTerminalTail => {
                write!(f, "program does not end in NEXT/RET/TRAP")
            }
            VerifyError::LoadWordsOutOfRange { load_words } => {
                write!(f, "load_words {load_words} outside 1..={DATA_WORDS}")
            }
        }
    }
}

impl std::error::Error for VerifyError {}

/// Verify a program; returns `Ok(())` or the first violation.
pub fn verify(p: &Program) -> Result<(), VerifyError> {
    let n = p.instrs.len();
    if n == 0 {
        return Err(VerifyError::Empty);
    }
    if n > MAX_INSTRS {
        return Err(VerifyError::TooLong { n });
    }
    if p.load_words == 0 || p.load_words as usize > DATA_WORDS {
        return Err(VerifyError::LoadWordsOutOfRange {
            load_words: p.load_words,
        });
    }
    for (pc, i) in p.instrs.iter().enumerate() {
        check_regs(pc, i)?;
        match i.op {
            Op::Ldd | Op::Std => {
                if i.imm < 0 || i.imm >= DATA_WORDS as i64 {
                    return Err(VerifyError::StaticOffsetOob {
                        pc,
                        imm: i.imm,
                        window: DATA_WORDS,
                    });
                }
            }
            Op::Spl | Op::Sps => {
                if i.imm < 0 || i.imm >= SP_WORDS as i64 {
                    return Err(VerifyError::StaticOffsetOob {
                        pc,
                        imm: i.imm,
                        window: SP_WORDS,
                    });
                }
            }
            op if op.is_jump() => {
                // Target n (one past the end) is allowed and traps at
                // runtime — still strictly forward.
                if i.imm <= pc as i64 || i.imm > n as i64 {
                    return Err(VerifyError::NonForwardJump {
                        pc,
                        target: i.imm,
                    });
                }
            }
            _ => {}
        }
    }
    if !p.instrs[n - 1].op.is_terminal() {
        return Err(VerifyError::NonTerminalTail);
    }
    Ok(())
}

fn check_regs(pc: usize, i: &Instr) -> Result<(), VerifyError> {
    for (reg, used) in [
        (i.a, i.op.uses_a()),
        (i.b, i.op.uses_b()),
        (i.c, i.op.uses_c()),
    ] {
        if used && reg as usize >= NREG {
            return Err(VerifyError::BadRegister { pc, reg });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prog(instrs: Vec<Instr>) -> Program {
        Program::new(instrs, 1)
    }

    #[test]
    fn accepts_minimal_ret() {
        let p = prog(vec![Instr::new(Op::Ret, 0, 0, 0, 0)]);
        assert!(verify(&p).is_ok());
    }

    #[test]
    fn rejects_empty() {
        assert_eq!(verify(&prog(vec![])), Err(VerifyError::Empty));
    }

    #[test]
    fn rejects_too_long() {
        let mut v = vec![Instr::new(Op::Nop, 0, 0, 0, 0); MAX_INSTRS];
        v.push(Instr::new(Op::Ret, 0, 0, 0, 0));
        assert!(matches!(
            verify(&prog(v)),
            Err(VerifyError::TooLong { .. })
        ));
    }

    #[test]
    fn rejects_backward_and_self_jump() {
        let p = prog(vec![
            Instr::new(Op::Nop, 0, 0, 0, 0),
            Instr::new(Op::Jmp, 0, 0, 0, 0),
            Instr::new(Op::Ret, 0, 0, 0, 0),
        ]);
        assert!(matches!(
            verify(&p),
            Err(VerifyError::NonForwardJump { pc: 1, .. })
        ));
        let p = prog(vec![
            Instr::new(Op::Jmp, 0, 0, 0, 0),
            Instr::new(Op::Ret, 0, 0, 0, 0),
        ]);
        assert!(matches!(
            verify(&p),
            Err(VerifyError::NonForwardJump { pc: 0, .. })
        ));
    }

    #[test]
    fn allows_jump_one_past_end() {
        let p = prog(vec![
            Instr::new(Op::Jmp, 0, 0, 0, 2),
            Instr::new(Op::Ret, 0, 0, 0, 0),
        ]);
        assert!(verify(&p).is_ok());
    }

    #[test]
    fn rejects_register_oob() {
        let p = prog(vec![
            Instr::new(Op::Movi, 16, 0, 0, 1),
            Instr::new(Op::Ret, 0, 0, 0, 0),
        ]);
        assert!(matches!(
            verify(&p),
            Err(VerifyError::BadRegister { reg: 16, .. })
        ));
        // unused fields may hold anything
        let p = prog(vec![
            Instr::new(Op::Movi, 1, 255, 255, 1),
            Instr::new(Op::Ret, 0, 0, 0, 0),
        ]);
        assert!(verify(&p).is_ok());
    }

    #[test]
    fn rejects_static_oob() {
        let p = prog(vec![
            Instr::new(Op::Ldd, 1, 0, 0, DATA_WORDS as i64),
            Instr::new(Op::Ret, 0, 0, 0, 0),
        ]);
        assert!(matches!(
            verify(&p),
            Err(VerifyError::StaticOffsetOob { .. })
        ));
        let p = prog(vec![
            Instr::new(Op::Sps, 1, 0, 0, -1),
            Instr::new(Op::Ret, 0, 0, 0, 0),
        ]);
        assert!(matches!(
            verify(&p),
            Err(VerifyError::StaticOffsetOob { .. })
        ));
    }

    #[test]
    fn rejects_nonterminal_tail() {
        let p = prog(vec![Instr::new(Op::Movi, 1, 0, 0, 1)]);
        assert_eq!(verify(&p), Err(VerifyError::NonTerminalTail));
    }

    #[test]
    fn rejects_bad_load_words() {
        let p = Program::new(vec![Instr::new(Op::Ret, 0, 0, 0, 0)], 0);
        assert!(matches!(
            verify(&p),
            Err(VerifyError::LoadWordsOutOfRange { .. })
        ));
        let p = Program::new(
            vec![Instr::new(Op::Ret, 0, 0, 0, 0)],
            DATA_WORDS as u8 + 1,
        );
        assert!(matches!(
            verify(&p),
            Err(VerifyError::LoadWordsOutOfRange { .. })
        ));
    }
}
