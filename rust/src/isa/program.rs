//! Program container + wire encoding.
//!
//! A `Program` is the unit shipped inside every offloaded request (paper
//! §4.1: the dispatch engine "encapsulates the ISA instructions (code)
//! along with the initial value of cur_ptr and scratch_pad into a network
//! request"). Requests and responses carry the same format so a traversal
//! can be continued on any memory node (paper §5).

use super::op::{Instr, Op};
use super::{DATA_WORDS, MAX_INSTRS};

/// Stable identity of a verified program. Memory-node accelerators cache
/// decoded programs by id so repeated requests skip re-decoding (and the
/// XLA engine batches lanes of the same program).
pub type ProgramId = u64;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Program {
    pub instrs: Vec<Instr>,
    /// Words of the data window the aggregated LOAD must fetch
    /// (1..=DATA_WORDS). Computed by the compiler's load-aggregation
    /// analysis (paper §4.1).
    pub load_words: u8,
    /// Whether any instruction stores to the data window — if so the
    /// memory pipeline writes the window back at iteration end.
    pub writes_data: bool,
    id: ProgramId,
}

impl Program {
    /// Build from parts; callers should run `verify` first (the
    /// constructor only computes derived fields).
    pub fn new(instrs: Vec<Instr>, load_words: u8) -> Self {
        let writes_data = instrs
            .iter()
            .any(|i| matches!(i.op, Op::Std | Op::Stx));
        let id = Self::fingerprint(&instrs, load_words);
        Self { instrs, load_words, writes_data, id }
    }

    pub fn id(&self) -> ProgramId {
        self.id
    }

    /// DRAM bytes one executed iteration moves: the aggregated load,
    /// doubled for mutating programs whose dirty window streams back
    /// out. Single source for the DES and live `mem_bytes` accounting
    /// — the two engines' byte parity is a conformance property, so
    /// the formula must not be duplicated.
    pub fn dram_bytes_per_iter(&self) -> u64 {
        let rw: u64 = if self.writes_data { 2 } else { 1 };
        rw * self.load_words as u64 * 8
    }

    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// FNV-1a over the canonical encoding — deterministic across nodes.
    fn fingerprint(instrs: &[Instr], load_words: u8) -> ProgramId {
        let mut h: u64 = 0xCBF29CE484222325;
        let mut push = |b: u8| {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001B3);
        };
        push(load_words);
        let mut buf = Vec::with_capacity(Instr::WIRE_SIZE);
        for i in instrs {
            buf.clear();
            i.encode(&mut buf);
            for &b in &buf {
                push(b);
            }
        }
        h
    }

    /// Wire encoding: `[n_instrs u16][load_words u8][flags u8]` then
    /// `n` 16-byte instructions.
    pub fn encode(&self) -> Vec<u8> {
        let mut out =
            Vec::with_capacity(4 + self.instrs.len() * Instr::WIRE_SIZE);
        out.extend_from_slice(&(self.instrs.len() as u16).to_le_bytes());
        out.push(self.load_words);
        out.push(self.writes_data as u8);
        for i in &self.instrs {
            i.encode(&mut out);
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Option<Program> {
        if buf.len() < 4 {
            return None;
        }
        let n = u16::from_le_bytes([buf[0], buf[1]]) as usize;
        let load_words = buf[2];
        if n == 0 || n > MAX_INSTRS || load_words as usize > DATA_WORDS {
            return None;
        }
        if buf.len() < 4 + n * Instr::WIRE_SIZE {
            return None;
        }
        let mut instrs = Vec::with_capacity(n);
        for k in 0..n {
            let off = 4 + k * Instr::WIRE_SIZE;
            instrs.push(Instr::decode(&buf[off..])?);
        }
        let p = Program::new(instrs, load_words);
        if buf[3] != p.writes_data as u8 {
            // the flags byte is derived from the instructions; a
            // mismatch means the bytes were not produced by `encode`
            return None;
        }
        Some(p)
    }

    pub fn wire_size(&self) -> usize {
        4 + self.instrs.len() * Instr::WIRE_SIZE
    }

    /// Dense form consumed by the XLA engine: `[MAX_INSTRS*4]` i32 opcode
    /// fields (TRAP-padded) + `[MAX_INSTRS]` i64 immediates — exactly the
    /// arrays `pack_program` produces on the Python side.
    pub fn pack(&self) -> (Vec<i32>, Vec<i64>) {
        let mut ops = vec![0i32; MAX_INSTRS * 4];
        let mut imm = vec![0i64; MAX_INSTRS];
        for slot in 0..MAX_INSTRS {
            ops[slot * 4] = Op::Trap as i32;
        }
        for (k, i) in self.instrs.iter().enumerate() {
            ops[k * 4] = i.op as i32;
            ops[k * 4 + 1] = i.a as i32;
            ops[k * 4 + 2] = i.b as i32;
            ops[k * 4 + 3] = i.c as i32;
            imm[k] = i.imm;
        }
        (ops, imm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Program {
        Program::new(
            vec![
                Instr::new(Op::Movi, 1, 0, 0, 42),
                Instr::new(Op::Sps, 1, 0, 0, 0),
                Instr::new(Op::Ret, 0, 0, 0, 0),
            ],
            3,
        )
    }

    #[test]
    fn encode_decode_round_trip() {
        let p = sample();
        let buf = p.encode();
        assert_eq!(buf.len(), p.wire_size());
        let q = Program::decode(&buf).unwrap();
        assert_eq!(p, q);
        assert_eq!(p.id(), q.id());
    }

    #[test]
    fn id_is_content_addressed() {
        let p = sample();
        let mut other = sample();
        assert_eq!(p.id(), other.id());
        other.instrs[0].imm = 43;
        let other = Program::new(other.instrs, other.load_words);
        assert_ne!(p.id(), other.id());
    }

    #[test]
    fn writes_data_detected() {
        assert!(!sample().writes_data);
        let p = Program::new(
            vec![
                Instr::new(Op::Movi, 1, 0, 0, 1),
                Instr::new(Op::Std, 1, 0, 0, 0),
                Instr::new(Op::Ret, 0, 0, 0, 0),
            ],
            1,
        );
        assert!(p.writes_data);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Program::decode(&[]).is_none());
        assert!(Program::decode(&[0, 0, 1, 0]).is_none()); // n == 0
        let p = sample();
        let mut buf = p.encode();
        buf.truncate(buf.len() - 1);
        assert!(Program::decode(&buf).is_none());
    }

    #[test]
    fn pack_pads_with_trap() {
        let p = sample();
        let (ops, imm) = p.pack();
        assert_eq!(ops.len(), MAX_INSTRS * 4);
        assert_eq!(imm.len(), MAX_INSTRS);
        assert_eq!(ops[0], Op::Movi as i32);
        assert_eq!(imm[0], 42);
        assert_eq!(ops[3 * 4], Op::Trap as i32);
        assert_eq!(ops[(MAX_INSTRS - 1) * 4], Op::Trap as i32);
    }
}
