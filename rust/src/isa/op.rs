//! Opcodes + instruction encoding.

/// PULSE opcode (paper Table 2, adapted restricted RISC subset).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Op {
    Nop = 0,
    /// `r[a] = data[imm]` — static word offset within the data window.
    Ldd = 1,
    /// `r[a] = data[r[b] + imm]` — dynamic; OOB traps.
    Ldx = 2,
    /// `data[imm] = r[a]`.
    Std = 3,
    /// `data[r[b] + imm] = r[a]` — dynamic; OOB traps.
    Stx = 4,
    /// `r[a] = sp[imm]`.
    Spl = 5,
    /// `r[a] = sp[r[b] + imm]` — dynamic; OOB traps.
    Splx = 6,
    /// `sp[imm] = r[a]`.
    Sps = 7,
    /// `sp[r[b] + imm] = r[a]` — dynamic; OOB traps.
    Spsx = 8,
    /// `r[a] = r[b]`.
    Mov = 9,
    /// `r[a] = imm`.
    Movi = 10,
    Add = 11,
    Sub = 12,
    Mul = 13,
    /// Truncated signed division; divisor 0 traps; MIN/-1 wraps.
    Div = 14,
    And = 15,
    Or = 16,
    Xor = 17,
    /// `r[a] = !r[b]` (bitwise).
    Not = 18,
    /// `r[a] = r[b] << (imm & 63)`.
    Shl = 19,
    /// `r[a] = ((u64) r[b]) >> (imm & 63)` (logical).
    Shr = 20,
    /// `r[a] = r[b] + imm`.
    Addi = 21,
    /// Forward conditional jumps: `if cmp(r[a], r[b]) pc = imm`.
    Jeq = 22,
    Jne = 23,
    Jlt = 24,
    Jle = 25,
    Jgt = 26,
    Jge = 27,
    /// Unconditional forward jump.
    Jmp = 28,
    /// End of iteration; `r0` holds the next `cur_ptr`.
    Next = 29,
    /// End of traversal; scratchpad is the result.
    Ret = 30,
    /// Explicit fault.
    Trap = 31,
}

pub const N_OPCODES: u8 = 32;

impl Op {
    pub fn from_u8(v: u8) -> Option<Op> {
        if v < N_OPCODES {
            // SAFETY: Op is repr(u8) with contiguous discriminants 0..32.
            Some(unsafe { std::mem::transmute::<u8, Op>(v) })
        } else {
            None
        }
    }

    pub fn is_jump(self) -> bool {
        matches!(
            self,
            Op::Jeq | Op::Jne | Op::Jlt | Op::Jle | Op::Jgt | Op::Jge | Op::Jmp
        )
    }

    pub fn is_terminal(self) -> bool {
        matches!(self, Op::Next | Op::Ret | Op::Trap)
    }

    /// Whether this op touches the data window (used by the cost model:
    /// these are the "memory" instructions fused into the aggregated
    /// LOAD, paper §4.1).
    pub fn touches_data(self) -> bool {
        matches!(self, Op::Ldd | Op::Ldx | Op::Std | Op::Stx)
    }

    pub fn uses_a(self) -> bool {
        !matches!(self, Op::Nop | Op::Jmp | Op::Next | Op::Ret | Op::Trap)
    }

    pub fn uses_b(self) -> bool {
        matches!(
            self,
            Op::Ldx
                | Op::Stx
                | Op::Splx
                | Op::Spsx
                | Op::Mov
                | Op::Add
                | Op::Sub
                | Op::Mul
                | Op::Div
                | Op::And
                | Op::Or
                | Op::Xor
                | Op::Not
                | Op::Shl
                | Op::Shr
                | Op::Addi
                | Op::Jeq
                | Op::Jne
                | Op::Jlt
                | Op::Jle
                | Op::Jgt
                | Op::Jge
        )
    }

    pub fn uses_c(self) -> bool {
        matches!(
            self,
            Op::Add | Op::Sub | Op::Mul | Op::Div | Op::And | Op::Or | Op::Xor
        )
    }
}

/// One instruction. 16-byte wire encoding: `op,a,b,c` bytes, 4 pad
/// bytes, then `imm` as little-endian i64.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Instr {
    pub op: Op,
    pub a: u8,
    pub b: u8,
    pub c: u8,
    pub imm: i64,
}

impl Instr {
    pub const WIRE_SIZE: usize = 16;

    pub fn new(op: Op, a: u8, b: u8, c: u8, imm: i64) -> Self {
        Self { op, a, b, c, imm }
    }

    pub fn encode(&self, out: &mut Vec<u8>) {
        out.push(self.op as u8);
        out.push(self.a);
        out.push(self.b);
        out.push(self.c);
        out.extend_from_slice(&[0u8; 4]);
        out.extend_from_slice(&self.imm.to_le_bytes());
    }

    pub fn decode(buf: &[u8]) -> Option<Instr> {
        if buf.len() < Self::WIRE_SIZE {
            return None;
        }
        let op = Op::from_u8(buf[0])?;
        if buf[4..8] != [0u8; 4] {
            // pad bytes are part of the canonical form: every byte of
            // a valid encoding is load-bearing, so corruption can
            // never hide in ignored padding
            return None;
        }
        let imm = i64::from_le_bytes(buf[8..16].try_into().ok()?);
        Some(Instr { op, a: buf[1], b: buf[2], c: buf[3], imm })
    }
}

impl std::fmt::Display for Instr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:?} a={} b={} c={} imm={}",
            self.op, self.a, self.b, self.c, self.imm
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opcode_round_trip() {
        for v in 0..N_OPCODES {
            let op = Op::from_u8(v).unwrap();
            assert_eq!(op as u8, v);
        }
        assert!(Op::from_u8(N_OPCODES).is_none());
        assert!(Op::from_u8(255).is_none());
    }

    #[test]
    fn instr_wire_round_trip() {
        let i = Instr::new(Op::Addi, 3, 7, 0, -1234567890123);
        let mut buf = Vec::new();
        i.encode(&mut buf);
        assert_eq!(buf.len(), Instr::WIRE_SIZE);
        assert_eq!(Instr::decode(&buf), Some(i));
    }

    #[test]
    fn decode_rejects_short_and_bad_opcode() {
        assert!(Instr::decode(&[0u8; 8]).is_none());
        let mut buf = vec![200u8; 16];
        buf[0] = 200;
        assert!(Instr::decode(&buf).is_none());
    }

    #[test]
    fn classification() {
        assert!(Op::Jeq.is_jump());
        assert!(!Op::Add.is_jump());
        assert!(Op::Ret.is_terminal());
        assert!(Op::Ldx.touches_data());
        assert!(!Op::Spl.touches_data());
        assert!(Op::Add.uses_c());
        assert!(!Op::Addi.uses_c());
    }
}
