//! PULSE instruction set architecture (paper §4.1, Table 2).
//!
//! A stripped-down RISC ISA with only the operations needed for
//! memory-centric pointer traversals: loads/stores against the per-
//! iteration 256 B `data` window and the 256 B `scratch_pad`, ALU ops,
//! register moves, *forward-only* conditional jumps (eBPF-style), and the
//! terminals `NEXT_ITER` / `RETURN` / `TRAP`.
//!
//! This module is the Rust-side single source of truth; the Python mirror
//! lives in `python/compile/kernels/isa.py` and the two are cross-checked
//! by `rust/tests/integration_runtime.rs` (native interpreter vs the AOT
//! XLA artifact) and the pytest suite (Pallas kernel vs oracle).

pub mod analyze;
pub mod asm;
pub mod cost;
pub mod op;
pub mod program;
pub mod verify;

pub use analyze::{
    analyze, render_verify_error, AbsVal, Analysis, Diag, DiagKind,
    Severity, SP_INPUTS_ALL,
};
pub use asm::Asm;
pub use cost::{CostModel, IterCost, DEFAULT_ETA};
pub use op::{Instr, Op};
pub use program::{Program, ProgramId};
pub use verify::{verify, VerifyError};

/// Number of general-purpose 64-bit registers. `r0` is `cur_ptr`.
pub const NREG: usize = 16;
/// Scratchpad size in 8-byte words (256 B, paper §3).
pub const SP_WORDS: usize = 32;
/// Data window size in 8-byte words (256 B aggregated LOAD, paper §4.1).
pub const DATA_WORDS: usize = 32;
/// Maximum instructions per iteration (bounded computation, paper §3).
pub const MAX_INSTRS: usize = 64;

/// Register index conventions shared with the compiler + Python mirror.
pub const R_CUR: u8 = 0;

/// Lane status after a logic-pipeline pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(i32)]
pub enum Status {
    /// Still executing — never escapes a verified program's pass.
    Running = 0,
    /// Iteration finished; `r0` holds the next `cur_ptr`.
    NextIter = 1,
    /// Traversal finished; the scratchpad is the result.
    Return = 2,
    /// Fault (div-by-zero, window OOB, explicit TRAP, runaway pc).
    Trap = 3,
}

impl Status {
    pub fn from_i32(v: i32) -> Status {
        match v {
            0 => Status::Running,
            1 => Status::NextIter,
            2 => Status::Return,
            _ => Status::Trap,
        }
    }
}
