//! Energy model (paper §6.1, Fig. 8; §6.2 Fig. 11).
//!
//! The real measurements used Xilinx XRT (FPGA power rails), Intel RAPL
//! (CPU package + DRAM) and Micron's DRAM calculator (ARM); here the
//! same quantities come from an analytic model calibrated to the
//! published component powers:
//!
//! * PULSE FPGA node: board static + per-pipeline dynamic power;
//! * PULSE-ASIC: the accelerator fabric scaled by the Kuon–Rose
//!   FPGA→ASIC gap [95] (≈14× dynamic power), DRAM + third-party IPs
//!   unscaled — matching the paper's conservative methodology;
//! * RPC: Xeon package share for the cores needed to saturate 25 GB/s +
//!   DRAM power;
//! * RPC-ARM: BlueField-2 SoC power with `arm_slowdown`× longer
//!   execution — which is how the wimpy cores end up *less* efficient
//!   per op (Fig. 8 WebService).
//!
//! Outputs are joules/op at saturation throughput: `E = P_node / tput`.

use crate::accel::AccelConfig;

#[derive(Debug, Clone, Copy)]
pub struct PowerModel {
    /// FPGA board static (network stack, clocking, idle fabric), W.
    pub fpga_static_w: f64,
    /// per logic pipeline, W.
    pub fpga_logic_w: f64,
    /// per memory pipeline (incl. controller share), W.
    pub fpga_mem_w: f64,
    /// on-board DRAM, W (unscaled for ASIC too).
    pub dram_w: f64,
    /// FPGA -> ASIC dynamic-power scale factor (Kuon & Rose ≈ 1/14).
    pub asic_scale: f64,
    /// Xeon package power per active core (incl. uncore share), W.
    pub xeon_core_w: f64,
    /// cores needed to saturate 25 GB/s of pointer chasing.
    pub xeon_cores_for_bw: usize,
    /// host DRAM power under load, W.
    pub host_dram_w: f64,
    /// BlueField-2 SoC under load, W.
    pub arm_soc_w: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        Self {
            fpga_static_w: 5.0,
            fpga_logic_w: 0.9,
            fpga_mem_w: 0.75,
            dram_w: 2.0,
            asic_scale: 1.0 / 14.0,
            xeon_core_w: 11.5,
            xeon_cores_for_bw: 5,
            host_dram_w: 4.5,
            arm_soc_w: 19.0,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EnergySystem {
    Pulse,
    PulseAsic,
    Rpc,
    RpcArm,
    CacheRpc,
}

impl EnergySystem {
    pub fn name(&self) -> &'static str {
        match self {
            EnergySystem::Pulse => "PULSE",
            EnergySystem::PulseAsic => "PULSE-ASIC",
            EnergySystem::Rpc => "RPC",
            EnergySystem::RpcArm => "RPC-ARM",
            EnergySystem::CacheRpc => "Cache+RPC",
        }
    }
}

impl PowerModel {
    /// Node power for a PULSE accelerator configuration.
    pub fn pulse_node_w(&self, cfg: &AccelConfig) -> f64 {
        self.fpga_static_w
            + self.fpga_logic_w * cfg.m_logic as f64
            + self.fpga_mem_w * cfg.n_mem as f64
            + self.dram_w
    }

    /// Same accelerator as an ASIC: fabric power scaled, DRAM + static
    /// I/O (network stack etc.) kept — the paper's upper bound.
    pub fn pulse_asic_node_w(&self, cfg: &AccelConfig) -> f64 {
        let fabric = self.fpga_logic_w * cfg.m_logic as f64
            + self.fpga_mem_w * cfg.n_mem as f64
            + self.fpga_static_w * 0.55; // fabric share of static
        let fixed = self.fpga_static_w * 0.45 + self.dram_w;
        fabric * self.asic_scale + fixed
    }

    pub fn rpc_node_w(&self) -> f64 {
        self.xeon_core_w * self.xeon_cores_for_bw as f64 + self.host_dram_w
    }

    pub fn arm_node_w(&self) -> f64 {
        self.arm_soc_w + self.host_dram_w * 0.5
    }

    pub fn node_w(&self, sys: EnergySystem, cfg: &AccelConfig) -> f64 {
        match sys {
            EnergySystem::Pulse => self.pulse_node_w(cfg),
            EnergySystem::PulseAsic => self.pulse_asic_node_w(cfg),
            EnergySystem::Rpc | EnergySystem::CacheRpc => self.rpc_node_w(),
            EnergySystem::RpcArm => self.arm_node_w(),
        }
    }

    /// Energy per operation in microjoules at saturation throughput.
    pub fn energy_per_op_uj(
        &self,
        sys: EnergySystem,
        cfg: &AccelConfig,
        tput_ops_per_s: f64,
    ) -> f64 {
        if tput_ops_per_s <= 0.0 {
            return f64::INFINITY;
        }
        self.node_w(sys, cfg) / tput_ops_per_s * 1e6
    }

    /// Fig. 11: performance-per-watt for an η sweep configuration.
    pub fn perf_per_watt(&self, cfg: &AccelConfig, tput: f64) -> f64 {
        tput / self.pulse_node_w(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_cfg() -> AccelConfig {
        AccelConfig::paper_default()
    }

    #[test]
    fn pulse_vs_rpc_energy_ratio_matches_paper() {
        // At equal (memory-bandwidth-saturating) throughput the paper
        // measures PULSE 4.5–5× lower energy/op than RPC.
        let p = PowerModel::default();
        let tput = 1.0e6;
        let pulse =
            p.energy_per_op_uj(EnergySystem::Pulse, &paper_cfg(), tput);
        let rpc = p.energy_per_op_uj(EnergySystem::Rpc, &paper_cfg(), tput);
        let ratio = rpc / pulse;
        assert!(
            (4.0..6.0).contains(&ratio),
            "RPC/PULSE energy ratio {ratio}"
        );
    }

    #[test]
    fn asic_gains_additional_6_to_7x() {
        let p = PowerModel::default();
        let tput = 1.0e6;
        let pulse =
            p.energy_per_op_uj(EnergySystem::Pulse, &paper_cfg(), tput);
        let asic = p.energy_per_op_uj(
            EnergySystem::PulseAsic,
            &paper_cfg(),
            tput,
        );
        let ratio = pulse / asic;
        assert!((2.0..8.0).contains(&ratio), "ASIC gain {ratio}");
    }

    #[test]
    fn arm_can_exceed_xeon_energy_per_op() {
        // With the 3.5× slowdown the ARM node's throughput drops
        // proportionally on CPU-bound workloads; energy/op rises above
        // the Xeon's (Fig. 8 WebService observation).
        let p = PowerModel::default();
        let xeon_tput = 1.0e6;
        let arm_tput = xeon_tput / 3.5;
        let cfg = paper_cfg();
        let e_x = p.energy_per_op_uj(EnergySystem::Rpc, &cfg, xeon_tput);
        let e_a = p.energy_per_op_uj(EnergySystem::RpcArm, &cfg, arm_tput);
        assert!(e_a > e_x, "arm {e_a} vs xeon {e_x}");
    }

    #[test]
    fn eta_sweep_perf_per_watt_improves_with_fewer_logic_pipes() {
        // Fig. 11: at a memory-bound workload, throughput is set by n;
        // dropping η (fewer logic pipes per mem pipe) removes idle logic
        // power. η: 1 -> 1/4 should give ~1.9× perf/W at equal n... the
        // paper varies n with m=1; emulate: m=1, n in {1, 4}, tput ∝ n.
        let p = PowerModel::default();
        let cfg1 = AccelConfig { m_logic: 1, n_mem: 1, coupled: false };
        let cfg4 = AccelConfig { m_logic: 1, n_mem: 4, coupled: false };
        let ppw1 = p.perf_per_watt(&cfg1, 1.0e6);
        let ppw4 = p.perf_per_watt(&cfg4, 4.0e6);
        let gain = ppw4 / ppw1;
        assert!((1.5..4.0).contains(&gain), "perf/W gain {gain}");
    }

    #[test]
    fn node_power_magnitudes_sane() {
        let p = PowerModel::default();
        let cfg = paper_cfg();
        assert!(p.pulse_node_w(&cfg) < 20.0);
        assert!(p.rpc_node_w() > 40.0);
        assert!(p.pulse_asic_node_w(&cfg) < p.pulse_node_w(&cfg));
    }
}
