//! Persistent serving engine: the live dataplane kept running between
//! requests, accepting submissions from foreign threads.
//!
//! [`LiveBackend::serve`](super::LiveBackend) spins shards up and joins
//! them per call — fine for a batch, useless for a server, where
//! requests arrive one at a time from many socket readers and the
//! shards must outlive every individual request. This module is the
//! long-lived form of the same dataplane:
//!
//! * shard workers are the *same* [`super::shard::run_shard`] bodies
//!   (one OS thread per memory node, owning that node's accelerator);
//! * the dispatcher thread plays the CPU-node role exactly as the
//!   per-run coordinator does (routing, yield budget grants, trap on
//!   unroutable pointers), but draws work from a bounded **inbox**
//!   that any thread holding an [`EngineHandle`] may `try_submit` to;
//! * backpressure is explicit end to end: a full inbox rejects at the
//!   caller (`SubmitError::Busy` — the serving tier answers BUSY),
//!   a full admission window parks up to `pending_cap` submissions,
//!   and past that the dispatcher completes the op with
//!   [`CompletionCode::Busy`] instead of queueing unboundedly;
//! * shutdown is a drain: ops admitted before the marker complete,
//!   later submissions answer [`CompletionCode::ShuttingDown`].
//!
//! One submission is one offloaded traversal — `{program, cur_ptr,
//! scratch_pad, budget}`, the paper's §5 request format. Application
//! stage chains (scans, multi-stage ops) are the *client library's*
//! job, exactly as in the paper's CPU-node library: the wire client
//! (`srv::loadgen::OpDriver`) chains stages by re-submitting, so the
//! engine never needs to know about `Op` shapes.
//!
//! No-deadlock discipline (same invariant as `live::queue`): at most
//! `window` jobs are in flight, every shard queue has capacity
//! `window + 1` (the `+1` absorbs the shutdown marker), so dispatch
//! and shard-to-shard forwarding never block; shards may block briefly
//! pushing replies into the inbox, but the dispatcher is its only
//! consumer and never blocks itself, so the system always drains.
//!
//! `sharded = false` degrades to an inline executor: the dispatcher
//! runs each traversal to completion on its own thread through
//! [`Rack::traverse_offloaded`] — the same always-offload semantics
//! as the shards (no η test, no CPU fallback, no dispatch cache), on
//! the functional substrate every model backend (cache / RPC) shares.
//! Results — status, scratchpad, iters, crossings — are identical to
//! the sharded path for any wire request; what changes is parallelism
//! (none) and therefore wall clock.

use std::sync::Arc;
use std::time::Instant;

use crate::compiler::CompiledIter;
use crate::isa::{Status, SP_WORDS};
use crate::mem::GAddr;
use crate::net::{RequestId, TraversalMsg};
use crate::obs::{
    AtomicHist, MetricsRegistry, OpTrace, Span, SpanKind, Trace,
    TraceConfig, TraceRing, Tracer,
};
use crate::rack::{Rack, ServeReport};

use super::metrics::{LiveRunStats, ShardStats};
use super::queue::{self, QueueSnapshot, QueueTx, TrySend};
use super::router::Router;
use super::shard::{run_shard, JobTiming, LiveJob, Reply, ShardMsg};

/// Tunables of the persistent engine.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Admission window: traversals in flight at once. The shard
    /// queues are sized `window + 1` from this.
    pub window: usize,
    /// Inbox capacity (submissions + shard replies + the shutdown
    /// marker share it). 0 = auto: `2 * window + 16`.
    pub inbox_capacity: usize,
    /// Submissions parked while the window is full before the
    /// dispatcher starts answering BUSY. 0 = auto: `window`.
    pub pending_cap: usize,
    /// Yield-continuation cap per traversal (mirrors the live
    /// coordinator's runaway-yield guard); past it the op traps.
    pub max_boosts: u32,
    /// True: one worker thread per memory node (the live dataplane).
    /// False: inline functional execution on the dispatcher thread.
    pub sharded: bool,
    /// Sampled tracing (see `obs/`). None = tracer disabled — no
    /// rings are allocated and every emission site is a bool test.
    pub trace: Option<TraceConfig>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            window: 64,
            inbox_capacity: 0,
            pending_cap: 0,
            max_boosts: 4096,
            sharded: true,
            trace: None,
        }
    }
}

/// How a submission ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompletionCode {
    /// Traversal executed; status is `Return` or `Trap`.
    Done(Status),
    /// Shed at the dispatcher: admission window and pending buffer
    /// both full. The op did not execute.
    Busy,
    /// Arrived after the shutdown marker. The op did not execute.
    ShuttingDown,
}

/// Phase-sliced engine-side latency breakdown of one served op,
/// present on a [`Completion`] only when its [`Submission`] carried an
/// admission stamp (`t0`). Slices are disjoint:
/// `queue_ns + exec_ns + transit_ns <= latency_ns`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PhaseSlices {
    /// Submission stamp → first shard pop (engine inbox + shard
    /// queue wait).
    pub queue_ns: u64,
    /// Sum of measured accelerator visit durations.
    pub exec_ns: u64,
    /// Inter-shard transit (forward/bounce/boost legs) plus the
    /// final reply leg back to the dispatcher.
    pub transit_ns: u64,
    /// Shard visits (pops) the traversal made.
    pub visits: u32,
    /// Engine admission index — joins the sampled-trace span stream
    /// (`obs::Span::op`) when `traced`.
    pub op: u64,
    /// Whether the tracer sampled this op.
    pub traced: bool,
}

/// Terminal result of one submission, delivered through its `done`
/// callback on the dispatcher thread (keep the callback cheap — it
/// runs inside the serving loop; a channel send is the intended use).
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    /// Caller's correlation tag, echoed verbatim.
    pub tag: u64,
    pub code: CompletionCode,
    /// Final scratchpad (zeroes when the op never executed).
    pub sp: [i64; SP_WORDS],
    pub iters: u64,
    pub crossings: u32,
    /// Dispatcher-observed service time (admission -> completion).
    pub latency_ns: u64,
    /// Phase attribution; `Some` iff the submission set `t0`.
    pub phases: Option<PhaseSlices>,
}

/// One offloaded traversal, submitted from any thread.
pub struct Submission {
    pub iter: Arc<CompiledIter>,
    pub start: GAddr,
    pub sp: [i64; SP_WORDS],
    /// Initial iteration budget; 0 = the rack's dispatch grant.
    pub budget: u32,
    /// Correlation tag echoed in the [`Completion`].
    pub tag: u64,
    /// Admission stamp (wire decode time). `Some` opts this op into
    /// phase-sliced attribution: the job carries a [`JobTiming`]
    /// through every hop and the completion carries [`PhaseSlices`].
    /// `None` (the default) keeps the hot path free of extra clock
    /// reads and histogram records.
    pub t0: Option<Instant>,
    /// Per-program execute histogram (`engine.execute.prog{id}`),
    /// recorded at completion when attribution is on.
    pub exec_hist: Option<Arc<AtomicHist>>,
    /// Invoked exactly once with the terminal result.
    pub done: Box<dyn FnOnce(Completion) + Send>,
}

impl std::fmt::Debug for Submission {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Submission")
            .field("start", &self.start)
            .field("budget", &self.budget)
            .field("tag", &self.tag)
            .finish_non_exhaustive()
    }
}

/// The dispatcher's single inbox message: foreign-thread submissions,
/// shard replies (via `From<Reply>`, see `run_shard`'s generic reply
/// sink), and the shutdown marker, multiplexed so the dispatcher can
/// block on exactly one queue.
#[derive(Debug)]
pub(crate) enum EngineMsg {
    Submit(Submission),
    Reply(Reply),
    Shutdown,
}

impl From<Reply> for EngineMsg {
    fn from(r: Reply) -> Self {
        EngineMsg::Reply(r)
    }
}

/// Why a [`EngineHandle::try_submit`] was rejected; carries the
/// submission back so the caller can answer its client.
pub enum SubmitError {
    /// Inbox full right now — answer BUSY upstream.
    Busy(Submission),
    /// Engine has exited (shutdown drained) — answer shutting-down.
    Down(Submission),
}

/// Cloneable submission endpoint; safe to hold on any thread.
pub struct EngineHandle {
    tx: QueueTx<EngineMsg>,
}

impl Clone for EngineHandle {
    fn clone(&self) -> Self {
        Self { tx: self.tx.clone() }
    }
}

impl EngineHandle {
    /// Non-blocking submission. A full inbox is the outermost
    /// backpressure edge: callers turn it into an explicit BUSY.
    pub fn try_submit(&self, sub: Submission) -> Result<(), SubmitError> {
        match self.tx.try_send(EngineMsg::Submit(sub)) {
            Ok(()) => Ok(()),
            Err(TrySend::Full(EngineMsg::Submit(s))) => {
                Err(SubmitError::Busy(s))
            }
            Err(TrySend::Disconnected(EngineMsg::Submit(s))) => {
                Err(SubmitError::Down(s))
            }
            Err(_) => unreachable!("try_submit only sends Submit"),
        }
    }

    /// Begin the drain: ops already admitted (or parked) complete;
    /// everything after the marker answers `ShuttingDown`. Idempotent
    /// in effect; best-effort once the engine is already gone.
    pub fn shutdown(&self) {
        let _ = self.tx.send(EngineMsg::Shutdown);
    }
}

/// Everything the engine observed over its lifetime, returned when
/// [`Engine::run`] exits.
#[derive(Debug, Default)]
pub struct EngineReport {
    /// Standard serving accounting (completions, latency percentiles,
    /// iters, crossings, mem/net bytes) — the same `ServeReport` every
    /// backend emits, so the serving tier feeds `BackendMetrics`.
    pub report: ServeReport,
    /// Shed at the dispatcher (window + pending both full).
    pub busy: u64,
    /// Rejected after the shutdown marker.
    pub rejected_shutdown: u64,
    /// Engine-internal view (shards, router, queues).
    pub run: LiveRunStats,
    /// Inbox counters; `rejects` is the BUSY count at the outer edge.
    pub inbox: QueueSnapshot,
    /// Drained spans of every sampled traversal, in causal order
    /// (empty unless `EngineConfig::trace` was set).
    pub trace: Trace,
}

/// The engine-side per-phase histograms (`engine.phase.*`), created
/// eagerly in [`Engine::run`] when a registry is attached so the
/// names are always present in STATS snapshots; they only accumulate
/// records for submissions that opted into attribution (`t0` set) —
/// an unattributed workload leaves every count at zero.
struct EnginePhaseHists {
    queue: Arc<AtomicHist>,
    execute: Arc<AtomicHist>,
    transit: Arc<AtomicHist>,
}

impl EnginePhaseHists {
    fn new(reg: &MetricsRegistry) -> Self {
        Self {
            queue: reg.hist("engine.phase.queue_wait"),
            execute: reg.hist("engine.phase.execute"),
            transit: reg.hist("engine.phase.transit"),
        }
    }

    fn record(&self, ph: &PhaseSlices) {
        self.queue.record(ph.queue_ns.max(1));
        self.execute.record(ph.exec_ns.max(1));
        self.transit.record(ph.transit_ns.max(1));
    }
}

/// The dispatcher side; create with [`Engine::new`], then call
/// [`Engine::run`] on the thread that owns the rack (it blocks until
/// the drain completes).
pub struct Engine {
    cfg: EngineConfig,
    rx: queue::QueueRx<EngineMsg>,
    tx: QueueTx<EngineMsg>,
    registry: Option<Arc<MetricsRegistry>>,
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> (Engine, EngineHandle) {
        let inbox_cap = if cfg.inbox_capacity == 0 {
            2 * cfg.window.max(1) + 16
        } else {
            cfg.inbox_capacity.max(2)
        };
        let (tx, rx) = queue::bounded::<EngineMsg>(inbox_cap);
        let handle = EngineHandle { tx: tx.clone() };
        (Engine { cfg, rx, tx, registry: None }, handle)
    }

    /// Register live queue-occupancy gauges into `reg` when the engine
    /// starts (`engine.inbox.depth`, per-shard depth and high-water
    /// mark). Gauges read relaxed counters at snapshot time; the
    /// engine's hot paths are untouched.
    pub fn set_registry(&mut self, reg: Arc<MetricsRegistry>) {
        self.registry = Some(reg);
    }

    /// Serve until a shutdown marker arrives and every admitted op has
    /// completed. Blocks the calling thread; shard workers (sharded
    /// mode) are scoped to this call.
    pub fn run(self, rack: &mut Rack) -> EngineReport {
        let window = self.cfg.window.max(1);
        let pending_cap = if self.cfg.pending_cap == 0 {
            window
        } else {
            self.cfg.pending_cap
        };
        let grant = rack.cfg.dispatch.max_iters;
        // bound on a client-supplied initial budget: no request may
        // pre-grant itself more than the boost machinery could ever
        // legitimately hand out (grant × (max_boosts + 1)), so one
        // request cannot pin a shard for 2^32 iterations
        let max_initial = grant
            .saturating_mul(self.cfg.max_boosts.saturating_add(1))
            .max(grant);
        let inbox_stats = self.rx.stats_handle();
        let tracer = match self.cfg.trace {
            Some(c) => Tracer::new(c),
            None => Tracer::disabled(),
        };
        let phase_hists = self.registry.as_ref().map(|reg| {
            let inbox = Arc::clone(&inbox_stats);
            reg.gauge_fn("engine.inbox.depth", move || {
                inbox.snapshot().depth() as f64
            });
            EnginePhaseHists::new(reg)
        });

        let mut report = EngineReport::default();
        if self.cfg.sharded {
            let shards = rack.cfg.nodes;
            let in_network = rack.cfg.in_network_routing;
            // shares the allocator's epoch-cached map snapshot instead
            // of deep-copying the RangeMap per engine start
            let router =
                Arc::new(Router::new(rack.alloc.publish_map()));
            let mut txs = Vec::with_capacity(shards);
            let mut rxs = Vec::with_capacity(shards);
            let mut qstats = Vec::with_capacity(shards);
            for _ in 0..shards {
                let (tx, rx) = queue::bounded::<ShardMsg>(window + 1);
                qstats.push(tx.stats_handle());
                txs.push(tx);
                rxs.push(rx);
            }
            if let Some(reg) = &self.registry {
                for (i, q) in qstats.iter().enumerate() {
                    let depth = Arc::clone(q);
                    reg.gauge_fn(
                        &format!("engine.shard{i}.queue_depth"),
                        move || depth.snapshot().depth() as f64,
                    );
                    let hwm = Arc::clone(q);
                    reg.gauge_fn(
                        &format!("engine.shard{i}.queue_hwm"),
                        move || hwm.snapshot().hwm as f64,
                    );
                }
            }
            let shard_stats: Vec<ShardStats> =
                std::thread::scope(|s| {
                    let tracer = &tracer;
                    let mut handles = Vec::with_capacity(shards);
                    for (accel, rx) in rack.memnodes.iter_mut().zip(rxs)
                    {
                        let peers = txs.clone();
                        let replies = self.tx.clone();
                        let router = Arc::clone(&router);
                        handles.push(s.spawn(move || {
                            run_shard(
                                accel, rx, peers, replies, router,
                                in_network, tracer,
                            )
                        }));
                    }
                    let mut d = Dispatcher {
                        txs: &txs,
                        router: router.as_ref(),
                        report: &mut report,
                        slots: (0..window).map(|_| None).collect(),
                        free: (0..window as u32).rev().collect(),
                        pending: std::collections::VecDeque::new(),
                        pending_cap,
                        inflight: 0,
                        grant,
                        max_initial,
                        max_boosts: self.cfg.max_boosts,
                        seq: 0,
                        draining: false,
                        tracer,
                        ring: tracer.make_ring(),
                        phase: phase_hists.as_ref(),
                    };
                    loop {
                        match self.rx.recv() {
                            Some(EngineMsg::Submit(sub)) => {
                                d.on_submit(sub)
                            }
                            Some(EngineMsg::Reply(r)) => d.on_reply(r),
                            Some(EngineMsg::Shutdown) => {
                                d.draining = true
                            }
                            // all senders gone incl. our own clone:
                            // unreachable while `self.tx` lives, but
                            // bail instead of spinning if it happens
                            None => break,
                        }
                        if d.draining
                            && d.inflight == 0
                            && d.pending.is_empty()
                        {
                            break;
                        }
                    }
                    tracer.park(d.ring);
                    for tx in &txs {
                        let _ = tx.send(ShardMsg::Shutdown);
                    }
                    drop(txs);
                    handles
                        .into_iter()
                        .map(|h| {
                            h.join().expect("engine shard panicked")
                        })
                        .collect()
                });
            report.run = LiveRunStats {
                shards: shard_stats,
                router: router.snapshot(),
                queues: qstats.iter().map(|q| q.snapshot()).collect(),
                replies: inbox_stats.snapshot(),
            };
            // answer submissions that raced in behind the marker
            // (inflight is 0, so only Submit messages can remain)
            while let Some(m) = self.rx.try_recv() {
                if let EngineMsg::Submit(sub) = m {
                    report.rejected_shutdown += 1;
                    finish_unserved(sub, CompletionCode::ShuttingDown);
                }
            }
        } else {
            // inline mode: every traversal runs to completion on this
            // thread with *live-engine* semantics (always offloaded,
            // no η test / CPU fallback / dispatch cache) and the same
            // per-request budget + boost cap the sharded dispatcher
            // applies — so the two modes answer any wire request with
            // the same status, scratchpad, iters, and crossings
            let mut inline_seq: u64 = 0;
            let mut ring = tracer.make_ring();
            loop {
                match self.rx.recv() {
                    Some(EngineMsg::Submit(sub)) => {
                        let born = Instant::now();
                        let op = inline_seq;
                        inline_seq += 1;
                        let traced = tracer.sampled(op);
                        // attribution: inbox wait is the queue slice;
                        // the whole traversal is one "visit"
                        let queue_ns = sub.t0.map(|t0| {
                            born.saturating_duration_since(t0)
                                .as_nanos() as u64
                        });
                        let o = if traced {
                            let mut ot = OpTrace {
                                ring: &mut ring,
                                op,
                                k: 0,
                            };
                            ot.push(
                                tracer.now_ns(),
                                SpanKind::Dispatch { stage: 0 },
                            );
                            let o = rack.traverse_offloaded_traced(
                                &sub.iter,
                                sub.start,
                                sub.sp,
                                sub.budget.min(max_initial),
                                self.cfg.max_boosts,
                                Some((&mut ot, &tracer)),
                            );
                            ot.push(
                                tracer.now_ns(),
                                SpanKind::Finish {
                                    trapped: o.status == Status::Trap,
                                },
                            );
                            o
                        } else {
                            rack.traverse_offloaded(
                                &sub.iter,
                                sub.start,
                                sub.sp,
                                sub.budget.min(max_initial),
                                self.cfg.max_boosts,
                            )
                        };
                        {
                            // same formula as the sharded finish path:
                            // request + response over the CPU links,
                            // plus one shard hop per crossing
                            let wire = TraversalMsg::wire_size_for(
                                &sub.iter.program,
                            )
                                as u64;
                            report.report.net_bytes += wire * 2
                                + o.crossings as u64 * wire;
                        }
                        let phases = queue_ns.map(|q| PhaseSlices {
                            queue_ns: q,
                            exec_ns: (born.elapsed().as_nanos()
                                as u64)
                                .max(1),
                            transit_ns: 0,
                            visits: 1,
                            op,
                            traced,
                        });
                        complete_done(
                            &mut report,
                            sub,
                            o.status,
                            o.sp,
                            o.iters as u64,
                            o.crossings,
                            born,
                            phases,
                            phase_hists.as_ref(),
                        );
                    }
                    Some(EngineMsg::Reply(_)) => {
                        unreachable!("no shards in inline mode")
                    }
                    Some(EngineMsg::Shutdown) | None => break,
                }
            }
            // answer anything that raced in behind the marker
            while let Some(m) = self.rx.try_recv() {
                if let EngineMsg::Submit(sub) = m {
                    report.rejected_shutdown += 1;
                    finish_unserved(sub, CompletionCode::ShuttingDown);
                }
            }
            tracer.park(ring);
        }
        report.inbox = inbox_stats.snapshot();
        report.trace = tracer.drain();
        report
    }
}

/// Deliver a served completion and fold it into the report (shared by
/// the sharded dispatcher and the inline executor so their accounting
/// cannot drift).
#[allow(clippy::too_many_arguments)]
fn complete_done(
    report: &mut EngineReport,
    sub: Submission,
    status: Status,
    sp: [i64; SP_WORDS],
    iters: u64,
    crossings: u32,
    born: Instant,
    phases: Option<PhaseSlices>,
    phase_hists: Option<&EnginePhaseHists>,
) {
    let lat = (born.elapsed().as_nanos() as u64).max(1);
    let r = &mut report.report;
    r.completed += 1;
    if status == Status::Trap {
        r.trapped += 1;
    }
    r.latency.record(lat);
    r.crossings.record(crossings as u64);
    if crossings > 0 {
        r.cross_node_requests += 1;
    }
    r.total_iters += iters;
    r.mem_bytes += iters * sub.iter.program.dram_bytes_per_iter();
    // attribution sinks: phase hists + the per-program execute
    // series. Both are no-ops (one test) on unattributed ops.
    if let Some(ph) = &phases {
        if let Some(h) = phase_hists {
            h.record(ph);
        }
        if let Some(h) = &sub.exec_hist {
            h.record(ph.exec_ns.max(1));
        }
    }
    (sub.done)(Completion {
        tag: sub.tag,
        code: CompletionCode::Done(status),
        sp,
        iters,
        crossings,
        latency_ns: lat,
        phases,
    });
}

/// Deliver a shed (BUSY / shutting-down) completion — the op never
/// executed, so nothing is folded into the serving report.
fn finish_unserved(sub: Submission, code: CompletionCode) {
    (sub.done)(Completion {
        tag: sub.tag,
        code,
        sp: [0i64; SP_WORDS],
        iters: 0,
        crossings: 0,
        latency_ns: 0,
        phases: None,
    });
}

/// One admitted traversal's dispatcher-side state.
struct EngSlot {
    sub: Submission,
    born: Instant,
    boosts: u32,
    /// Admission index (trace identity; see `obs/README.md`).
    op: u64,
    /// Causal span counter, synced from each reply's job.
    trace_k: u32,
    traced: bool,
    /// Phase accounting, synced from each reply's job (Some iff the
    /// submission opted in via `t0`).
    timing: Option<JobTiming>,
}

/// The CPU-node role over the persistent inbox: admission window,
/// yield grants, routing, completion — the live coordinator's state
/// machine minus stage chaining (one submission = one traversal).
struct Dispatcher<'a> {
    txs: &'a [QueueTx<ShardMsg>],
    router: &'a Router,
    report: &'a mut EngineReport,
    slots: Vec<Option<EngSlot>>,
    free: Vec<u32>,
    pending: std::collections::VecDeque<Submission>,
    pending_cap: usize,
    inflight: usize,
    grant: u32,
    /// Cap on a client-supplied initial budget (see `Engine::run`).
    max_initial: u32,
    max_boosts: u32,
    seq: u64,
    draining: bool,
    tracer: &'a Tracer,
    /// Dispatcher-side span ring (dispatch/boost/finish hops).
    ring: TraceRing,
    /// Engine-phase histograms (present when a registry is attached).
    phase: Option<&'a EnginePhaseHists>,
}

impl Dispatcher<'_> {
    /// Emit one span for `token`'s traversal and advance its causal
    /// counter (bool test when untraced).
    fn emit(&mut self, token: u32, kind: SpanKind) {
        let slot = self.slots[token as usize].as_mut().unwrap();
        if slot.traced {
            self.ring.push(Span {
                op: slot.op,
                k: slot.trace_k,
                t_ns: self.tracer.now_ns(),
                kind,
            });
            slot.trace_k += 1;
        }
    }

    /// Wrap a message with its slot's trace identity (and phase
    /// accounting) for the wire.
    fn job(&self, token: u32, msg: TraversalMsg) -> LiveJob {
        let slot = self.slots[token as usize].as_ref().unwrap();
        LiveJob {
            token,
            op: slot.op,
            trace_k: slot.trace_k,
            traced: slot.traced,
            timing: slot.timing,
            msg,
        }
    }

    /// Resume span emission (and phase accounting) where the shard
    /// left off for this op.
    fn sync_trace(&mut self, job: &LiveJob) {
        if job.traced || job.timing.is_some() {
            let slot =
                self.slots[job.token as usize].as_mut().unwrap();
            slot.trace_k = job.trace_k;
            slot.timing = job.timing;
        }
    }
    fn on_submit(&mut self, sub: Submission) {
        if self.draining {
            self.report.rejected_shutdown += 1;
            finish_unserved(sub, CompletionCode::ShuttingDown);
            return;
        }
        if self.inflight < self.slots.len() {
            self.admit(sub);
        } else if self.pending.len() < self.pending_cap {
            self.pending.push_back(sub);
        } else {
            self.report.busy += 1;
            finish_unserved(sub, CompletionCode::Busy);
        }
    }

    fn admit(&mut self, sub: Submission) {
        let token = self
            .free
            .pop()
            .expect("inflight < window implies a free token");
        let budget = if sub.budget == 0 {
            self.grant
        } else {
            sub.budget.min(self.max_initial)
        };
        let op = self.seq;
        let id = RequestId { cpu_node: 0, seq: self.seq };
        self.seq += 1;
        let msg = TraversalMsg::request(
            id,
            Arc::clone(&sub.iter.program),
            sub.start,
            sub.sp,
            budget,
        );
        // the timing clock starts at the submitter's t0, so the
        // engine inbox wait lands in the queue slice
        let timing = sub.t0.map(JobTiming::start);
        self.slots[token as usize] = Some(EngSlot {
            sub,
            born: Instant::now(),
            boosts: 0,
            op,
            trace_k: 0,
            traced: self.tracer.sampled(op),
            timing,
        });
        self.inflight += 1;
        self.emit(token, SpanKind::Dispatch { stage: 0 });
        self.send(token, msg);
    }

    /// Route + enqueue; unroutable pointers (and shard-teardown races)
    /// complete as traps, exactly like the live coordinator.
    fn send(&mut self, token: u32, msg: TraversalMsg) {
        match self.router.route(msg.cur_ptr, false) {
            Some(shard) => {
                let job = self.job(token, msg);
                if let Err(ShardMsg::Job(job)) =
                    self.txs[shard as usize].send(ShardMsg::Job(job))
                {
                    self.finish(token, Status::Trap, &job.msg);
                }
            }
            None => self.finish(token, Status::Trap, &msg),
        }
    }

    fn on_reply(&mut self, reply: Reply) {
        match reply {
            Reply::Done(job) => {
                self.sync_trace(&job);
                let LiveJob { token, msg, .. } = job;
                self.finish(token, msg.status, &msg)
            }
            Reply::Yield(job) => {
                self.sync_trace(&job);
                let LiveJob { token, mut msg, .. } = job;
                let boosts = {
                    let slot =
                        self.slots[token as usize].as_mut().unwrap();
                    slot.boosts += 1;
                    slot.boosts
                };
                if boosts > self.max_boosts {
                    self.finish(token, Status::Trap, &msg);
                } else {
                    msg.max_iters += self.grant;
                    // grant = the new *total* budget after the boost
                    self.emit(
                        token,
                        SpanKind::Boost { grant: msg.max_iters },
                    );
                    self.send(token, msg);
                }
            }
            // PULSE-ACC mode: the bounce returns here for re-routing
            Reply::Bounced(job) => {
                self.sync_trace(&job);
                let LiveJob { token, msg, .. } = job;
                self.send(token, msg)
            }
        }
    }

    fn finish(&mut self, token: u32, status: Status, msg: &TraversalMsg) {
        self.emit(
            token,
            SpanKind::Finish { trapped: status == Status::Trap },
        );
        let mut slot = self.slots[token as usize].take().unwrap();
        self.free.push(token);
        self.inflight -= 1;
        let wire = msg.wire_size() as u64;
        self.report.report.net_bytes +=
            wire * 2 + msg.node_crossings as u64 * wire;
        let phases = slot.timing.take().map(|mut t| {
            // close the last open leg (shard → dispatcher reply, or
            // admission → trap when the op never reached a shard)
            let d = t.enq.elapsed().as_nanos() as u64;
            if t.visits == 0 {
                t.queue_ns += d;
            } else {
                t.transit_ns += d;
            }
            PhaseSlices {
                queue_ns: t.queue_ns,
                exec_ns: t.exec_ns,
                transit_ns: t.transit_ns,
                visits: t.visits,
                op: slot.op,
                traced: slot.traced,
            }
        });
        complete_done(
            self.report,
            slot.sub,
            status,
            msg.sp,
            msg.iters_done as u64,
            msg.node_crossings,
            slot.born,
            phases,
            self.phase,
        );
        if let Some(next) = self.pending.pop_front() {
            self.admit(next);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ds::{ForwardList, HashMapDs};
    use crate::rack::RackConfig;
    use std::sync::mpsc;

    /// Drive `n` hash lookups through an engine and return the
    /// completions in tag order.
    fn run_lookups(sharded: bool, nodes: usize, n: u64) -> Vec<Completion> {
        let mut rack = Rack::new(RackConfig::small(nodes));
        let mut m = HashMapDs::build(&mut rack, 64);
        for i in 0..400 {
            m.insert(&mut rack, i, i * 3);
        }
        let subs: Vec<(Arc<crate::compiler::CompiledIter>, u64, i64)> =
            (0..n)
                .map(|i| {
                    let key = (i % 400) as i64;
                    (m.find_program(), m.bucket_ptr(key), key)
                })
                .collect();
        let (engine, handle) = Engine::new(EngineConfig {
            window: 8,
            sharded,
            ..EngineConfig::default()
        });
        let (ctx, crx) = mpsc::channel::<Completion>();
        let mut out = std::thread::scope(|s| {
            let eng = s.spawn(|| engine.run(&mut rack));
            let mut got = Vec::new();
            for (tag, (iter, start, key)) in subs.into_iter().enumerate()
            {
                let mut sp = [0i64; SP_WORDS];
                sp[0] = key;
                let ctx = ctx.clone();
                // bounded inbox: spin on Busy (test-only; the server
                // answers BUSY to its client instead)
                let mut sub = Submission {
                    iter,
                    start,
                    sp,
                    budget: 0,
                    tag: tag as u64,
                    t0: None,
                    exec_hist: None,
                    done: Box::new(move |c| {
                        let _ = ctx.send(c);
                    }),
                };
                loop {
                    match handle.try_submit(sub) {
                        Ok(()) => break,
                        Err(SubmitError::Busy(s)) => {
                            sub = s;
                            std::thread::yield_now();
                        }
                        Err(SubmitError::Down(_)) => {
                            panic!("engine exited early")
                        }
                    }
                }
            }
            for _ in 0..n {
                got.push(crx.recv().expect("completion"));
            }
            handle.shutdown();
            let rep = eng.join().unwrap();
            assert_eq!(rep.report.completed, n);
            assert_eq!(rep.report.trapped, 0);
            assert_eq!(rep.rejected_shutdown, 0);
            got
        });
        out.sort_by_key(|c| c.tag);
        out
    }

    #[test]
    fn sharded_engine_serves_foreign_thread_submissions() {
        let got = run_lookups(true, 2, 200);
        assert_eq!(got.len(), 200);
        for (i, c) in got.iter().enumerate() {
            assert_eq!(c.code, CompletionCode::Done(Status::Return));
            assert_eq!(c.sp[1], ((i % 400) as i64) * 3, "op {i}");
            assert!(c.latency_ns >= 1);
        }
    }

    #[test]
    fn inline_engine_matches_sharded_results() {
        let a = run_lookups(true, 2, 64);
        let b = run_lookups(false, 2, 64);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.sp, y.sp);
            assert_eq!(x.code, y.code);
        }
    }

    /// Queue-wait sanity (both executor modes): a submission carrying
    /// an admission stamp gets back monotone, disjoint phase slices
    /// that sum to at most the dispatcher-observed latency, with at
    /// least one shard visit — and the stamp is the only trigger (no
    /// stamp → no phases).
    #[test]
    fn attribution_slices_are_monotone_and_bounded() {
        for sharded in [true, false] {
            let mut rack = Rack::new(RackConfig::small(2));
            let mut m = HashMapDs::build(&mut rack, 32);
            for i in 0..64 {
                m.insert(&mut rack, i, i + 100);
            }
            let (engine, handle) = Engine::new(EngineConfig {
                window: 4,
                sharded,
                ..EngineConfig::default()
            });
            let (ctx, crx) = mpsc::channel::<Completion>();
            std::thread::scope(|s| {
                let eng = s.spawn(|| engine.run(&mut rack));
                let mut starts = Vec::with_capacity(32);
                for tag in 0..32u64 {
                    let mut sp = [0i64; SP_WORDS];
                    sp[0] = (tag % 64) as i64;
                    let ctx = ctx.clone();
                    let t0 = Instant::now();
                    starts.push(t0);
                    let mut sub = Submission {
                        iter: m.find_program(),
                        start: m.bucket_ptr((tag % 64) as i64),
                        sp,
                        budget: 0,
                        tag,
                        // even tags opt in, odd tags stay dark
                        t0: (tag % 2 == 0).then_some(t0),
                        exec_hist: None,
                        done: Box::new(move |c| {
                            let _ = ctx.send(c);
                        }),
                    };
                    loop {
                        match handle.try_submit(sub) {
                            Ok(()) => break,
                            Err(SubmitError::Busy(s)) => {
                                sub = s;
                                std::thread::yield_now();
                            }
                            Err(SubmitError::Down(_)) => {
                                panic!("engine exited early")
                            }
                        }
                    }
                }
                for _ in 0..32 {
                    let c = crx.recv().unwrap();
                    assert_eq!(
                        c.code,
                        CompletionCode::Done(Status::Return)
                    );
                    if c.tag % 2 == 0 {
                        let ph = c.phases.unwrap_or_else(|| {
                            panic!("tag {} lost its phases", c.tag)
                        });
                        let sum = ph.queue_ns
                            + ph.exec_ns
                            + ph.transit_ns;
                        // slices partition [t0, done], so their sum
                        // is bounded by any wall clock that brackets
                        // that interval (client-side here — latency_ns
                        // starts later, at admission)
                        let wall = starts[c.tag as usize]
                            .elapsed()
                            .as_nanos() as u64;
                        assert!(
                            sum <= wall,
                            "slices {sum} exceed wall {wall} \
                             (sharded {sharded})"
                        );
                        assert!(ph.visits >= 1);
                        assert!(ph.exec_ns >= 1);
                    } else {
                        assert!(
                            c.phases.is_none(),
                            "unstamped op grew phases"
                        );
                    }
                }
                handle.shutdown();
                let _ = eng.join().unwrap();
            });
        }
    }

    /// Both executor modes must honor the per-request budget and the
    /// boost cap identically: a walk longer than budget × (boosts+1)
    /// traps in sharded AND inline mode, with matching iteration
    /// counts (regression for the inline path silently ignoring both).
    #[test]
    fn budget_and_boost_cap_agree_across_modes() {
        let run_one = |sharded: bool| -> Completion {
            let mut rack = Rack::new(RackConfig::small(1));
            let mut l = ForwardList::new();
            for i in 1..=500 {
                l.push(&mut rack, i);
            }
            let sum = l.sum_program();
            // max_boosts 0: the very first yield (at the 50-iteration
            // budget) must trap instead of being re-granted
            let (engine, handle) = Engine::new(EngineConfig {
                window: 1,
                max_boosts: 0,
                sharded,
                ..EngineConfig::default()
            });
            let (ctx, crx) = mpsc::channel::<Completion>();
            std::thread::scope(|s| {
                let eng = s.spawn(|| engine.run(&mut rack));
                handle
                    .try_submit(Submission {
                        iter: sum.clone(),
                        start: l.head,
                        sp: [0i64; SP_WORDS],
                        budget: 50,
                        tag: 0,
                        t0: None,
                        exec_hist: None,
                        done: Box::new(move |c| {
                            let _ = ctx.send(c);
                        }),
                    })
                    .ok()
                    .expect("submit");
                let c = crx.recv().unwrap();
                handle.shutdown();
                let _ = eng.join().unwrap();
                c
            })
        };
        let a = run_one(true);
        let b = run_one(false);
        // 500-hop walk, 50-iteration budget, no boosts allowed: both
        // modes must trap at the budget rather than run to completion
        assert_eq!(a.code, CompletionCode::Done(Status::Trap));
        assert_eq!(b.code, a.code, "inline diverged from sharded");
        assert_eq!(b.iters, a.iters, "iteration accounting diverged");
        assert!(
            a.iters >= 1 && a.iters < 500,
            "budget/boost cap was not applied (iters={})",
            a.iters
        );
    }

    #[test]
    fn pending_overflow_answers_busy_without_executing() {
        // window 1, pending 1, and a slow op hogging the slot: the
        // burst behind it must split into parked + BUSY, never a hang
        let mut rack = Rack::new(RackConfig::small(1));
        let mut l = ForwardList::new();
        for i in 1..=50_000 {
            l.push(&mut rack, i);
        }
        let sum = l.sum_program();
        let (engine, handle) = Engine::new(EngineConfig {
            window: 1,
            pending_cap: 1,
            inbox_capacity: 32,
            sharded: true,
            ..EngineConfig::default()
        });
        let (ctx, crx) = mpsc::channel::<Completion>();
        std::thread::scope(|s| {
            let eng = s.spawn(|| engine.run(&mut rack));
            let n = 8u64;
            for tag in 0..n {
                let ctx = ctx.clone();
                handle
                    .try_submit(Submission {
                        iter: sum.clone(),
                        start: l.head,
                        sp: [0i64; SP_WORDS],
                        budget: 0,
                        tag,
                        t0: None,
                        exec_hist: None,
                        done: Box::new(move |c| {
                            let _ = ctx.send(c);
                        }),
                    })
                    .ok()
                    .expect("inbox sized for the whole burst");
            }
            let mut done = 0u64;
            let mut busy = 0u64;
            for _ in 0..n {
                match crx.recv().unwrap().code {
                    CompletionCode::Done(Status::Return) => done += 1,
                    CompletionCode::Busy => busy += 1,
                    other => panic!("unexpected {other:?}"),
                }
            }
            handle.shutdown();
            let rep = eng.join().unwrap();
            assert_eq!(done + busy, n);
            assert!(busy >= 1, "burst of {n} through window 1 never shed");
            assert_eq!(rep.busy, busy);
            assert_eq!(rep.report.completed, done);
        });
    }

    #[test]
    fn shutdown_rejects_late_submissions() {
        let mut rack = Rack::new(RackConfig::small(1));
        let mut m = HashMapDs::build(&mut rack, 16);
        m.insert(&mut rack, 1, 10);
        let find = m.find_program();
        let start = m.bucket_ptr(1);
        let (engine, handle) = Engine::new(EngineConfig {
            window: 2,
            sharded: true,
            ..EngineConfig::default()
        });
        let (ctx, crx) = mpsc::channel::<Completion>();
        std::thread::scope(|s| {
            let eng = s.spawn(|| engine.run(&mut rack));
            handle.shutdown();
            // raced-in-behind-the-marker submission: either the
            // dispatcher answers ShuttingDown, or the inbox is already
            // disconnected and try_submit reports Down
            let ctx2 = ctx.clone();
            let late = handle.try_submit(Submission {
                iter: find.clone(),
                start,
                sp: [0i64; SP_WORDS],
                budget: 0,
                tag: 9,
                t0: None,
                exec_hist: None,
                done: Box::new(move |c| {
                    let _ = ctx2.send(c);
                }),
            });
            match late {
                Ok(()) => {
                    // the marker may still be ahead of us in FIFO
                    // order (served normally), behind us (answered
                    // ShuttingDown), or — in the narrow teardown race
                    // — the send can land after the engine's final
                    // drain sweep, in which case no completion comes
                    // (the serving tier surfaces that as a connection
                    // close; see srv/README.md)
                    match crx.recv_timeout(
                        std::time::Duration::from_secs(2),
                    ) {
                        Ok(c) => assert!(matches!(
                            c.code,
                            CompletionCode::ShuttingDown
                                | CompletionCode::Done(Status::Return)
                        )),
                        Err(_) => {}
                    }
                }
                Err(SubmitError::Down(_)) => {}
                Err(SubmitError::Busy(_)) => {
                    panic!("empty inbox reported Busy")
                }
            }
            let _ = eng.join().unwrap();
        });
    }
}
