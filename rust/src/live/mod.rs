//! Live multi-threaded traversal engine: the PULSE dataplane executed
//! for real instead of simulated.
//!
//! Every memory node of the rack becomes a *shard* — an OS thread that
//! owns that node's [`Accelerator`] (DRAM region, TCAM range table,
//! native logic engine) and serves a bounded MPSC request queue. The
//! coordinator (the calling thread) plays the CPU node's dispatch
//! engine; a shared [`Router`] snapshot of the switch's coarse
//! `RangeMap` plays the Tofino pipeline. Mapping onto paper Fig. 6:
//!
//! 1. dispatch: coordinator resolves an op stage, builds the
//!    `TraversalMsg`, routes the start pointer;
//! 2. the owning shard pops the request and runs iterations against
//!    its local DRAM (`Accelerator::visit`);
//! 3. a finished traversal is answered to the reply queue;
//! 4. a non-local pointer bounces: with in-network routing the shard
//!    forwards the request *directly* to the owner's queue (steps
//!    4→6); in PULSE-ACC mode it returns to the coordinator, which
//!    re-routes it — the extra hop Fig. 9 measures;
//! 5. budget exhaustion yields to the coordinator, which grants more
//!    iterations and re-dispatches (paper §3).
//!
//! Everything above the wire is shared with the DES: the same ops,
//! stage chains, `TraversalMsg` format, accelerator visit logic, and
//! functional heap — so [`LiveBackend`] slots behind
//! [`TraversalBackend`] next to Rack/Cache/RPC and must produce
//! identical scratchpads and iteration counts (enforced by
//! `rust/tests/integration_live.rs`). What changes is *time*: the DES
//! reports modeled virtual time; the live engine reports wall-clock
//! latency/throughput of real threads contending on real queues.
//!
//! Unlike the DES, the live coordinator offloads every stage (its
//! shards *are* general-purpose cores, so the `t_c ≤ η·t_d` FPGA
//! offload test and the CPU fallback path do not apply), and links are
//! loss-free (in-process queues don't drop), so there is no
//! retransmission machinery.

// Hot-path modules keep clones honest: a clone the borrow checker
// would let us drop is a bug here, not a style nit.
#![deny(clippy::redundant_clone)]

pub mod engine;
pub mod metrics;
pub mod queue;
pub mod router;
mod shard;

pub use self::engine::{
    Completion, CompletionCode, Engine, EngineConfig, EngineHandle,
    EngineReport, Submission, SubmitError,
};
pub use self::metrics::{LiveRunStats, ShardStats};
pub use self::router::{Router, RouterStats};

use std::sync::Arc;
use std::time::Instant;

use crate::backend::{BackendMetrics, TraversalBackend};
use crate::isa::{Status, SP_WORDS};
use crate::net::{RequestId, TraversalMsg};
use crate::obs::{
    Span, SpanKind, Trace, TraceConfig, TraceRing, Tracer, TracerStats,
};
use crate::rack::{Op, Rack, ServeReport};
use crate::util::CachePadded;

use self::queue::QueueTx;
use self::shard::{run_shard, LiveJob, Reply, ShardMsg};

/// Tunables of the live engine.
#[derive(Debug, Clone, Copy)]
pub struct LiveConfig {
    /// Per-shard request-queue capacity. 0 = auto: concurrency + 1,
    /// which makes every send non-blocking (see `live::queue` docs).
    /// A smaller explicit capacity instead clamps the admitted window
    /// to `capacity - 1` so the no-deadlock invariant still holds.
    pub queue_capacity: usize,
    /// Yield-continuation cap per stage, mirroring `Rack::traverse`'s
    /// runaway-yield guard; past it the stage traps.
    pub max_budget_boosts: u32,
}

impl Default for LiveConfig {
    fn default() -> Self {
        Self { queue_capacity: 0, max_budget_boosts: 4096 }
    }
}

/// The live engine behind the unified backend trait.
pub struct LiveBackend {
    pub rack: Rack,
    pub live_cfg: LiveConfig,
    totals: ServeReport,
    last_run: Option<LiveRunStats>,
    record_results: bool,
    last_results: Vec<[i64; SP_WORDS]>,
    tracer: Tracer,
}

impl LiveBackend {
    pub fn new(rack: Rack) -> Self {
        Self::with_config(rack, LiveConfig::default())
    }

    pub fn with_config(rack: Rack, live_cfg: LiveConfig) -> Self {
        Self {
            rack,
            live_cfg,
            totals: ServeReport::default(),
            last_run: None,
            record_results: false,
            last_results: Vec::new(),
            tracer: Tracer::disabled(),
        }
    }

    /// Enable sampled tracing for subsequent serves (see `obs/`).
    pub fn enable_trace(&mut self, cfg: TraceConfig) {
        self.tracer = Tracer::new(cfg);
    }

    /// Tracer overhead counters (all zero while tracing is disabled —
    /// the zero-cost contract pinned in `tests/conformance.rs`).
    pub fn tracer_stats(&self) -> TracerStats {
        self.tracer.stats()
    }

    /// Drain spans recorded since the last drain, in causal order.
    pub fn take_trace(&mut self) -> Trace {
        self.tracer.drain()
    }

    /// Capture every op's final scratchpad during serves (issue
    /// order). Costs one copy per op; off by default. Used by the
    /// cross-backend equivalence tests.
    pub fn record_results(&mut self, on: bool) {
        self.record_results = on;
    }

    /// Final scratchpads of the last serve, in issue order (empty
    /// unless `record_results(true)`).
    pub fn last_results(&self) -> &[[i64; SP_WORDS]] {
        &self.last_results
    }

    /// Engine-internal stats of the last serve run.
    pub fn last_run(&self) -> Option<&LiveRunStats> {
        self.last_run.as_ref()
    }

    fn serve_impl(
        &mut self,
        source: OpSource<'_>,
        concurrency: usize,
    ) -> ServeReport {
        let wall_start = Instant::now();
        let shards = self.rack.cfg.nodes;
        let in_network = self.rack.cfg.in_network_routing;
        let grant = self.rack.cfg.dispatch.max_iters;
        let max_boosts = self.live_cfg.max_budget_boosts;

        // No-deadlock sizing: at most `window` messages are in flight
        // (one per admitted op) and each queue absorbs one extra
        // shutdown marker, so capacity >= window + 1 means no send can
        // block on a full queue and forwarding cycles cannot jam.
        let (cap, window) = if self.live_cfg.queue_capacity == 0 {
            (concurrency.max(1) + 1, concurrency.max(1))
        } else {
            let cap = self.live_cfg.queue_capacity.max(2);
            (cap, concurrency.clamp(1, cap - 1))
        };

        // shares the allocator's published snapshot: router
        // construction is an Arc bump, not a RangeMap deep copy
        let router =
            Arc::new(Router::new(self.rack.alloc.publish_map()));
        let mut txs = Vec::with_capacity(shards);
        let mut rxs = Vec::with_capacity(shards);
        let mut qstats = Vec::with_capacity(shards);
        for _ in 0..shards {
            let (tx, rx) = queue::bounded::<ShardMsg>(cap);
            qstats.push(tx.stats_handle());
            txs.push(tx);
            rxs.push(rx);
        }
        let (rtx, rrx) = queue::bounded::<Reply>(window + shards + 1);
        let reply_stats = rtx.stats_handle();

        let mut report = ServeReport::default();
        let record = self.record_results;
        // reserve up front so recording never grows the vector inside
        // the timed region (batch size is known; generators amortize)
        let mut results: Vec<(u64, [i64; SP_WORDS])> = Vec::new();
        if record {
            if let OpSource::Batch(ops) = &source {
                results.reserve(ops.len());
            }
        }

        let tracer = &self.tracer;
        let memnodes = &mut self.rack.memnodes;
        let shard_stats: Vec<ShardStats> = std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(shards);
            for (accel, rx) in memnodes.iter_mut().zip(rxs) {
                let peers = txs.clone();
                let replies = rtx.clone();
                let router = Arc::clone(&router);
                handles.push(s.spawn(move || {
                    run_shard(
                        accel, rx, peers, replies, router, in_network,
                        tracer,
                    )
                }));
            }
            // only shards hold reply senders now: if every worker dies
            // (panic), rrx.recv() disconnects instead of blocking
            // forever, and the joins below surface the panic
            drop(rtx);

            let mut coord = Coordinator {
                txs: &txs,
                router: router.as_ref(),
                report: &mut report,
                source,
                slots: (0..window)
                    .map(|_| CachePadded::new(None))
                    .collect(),
                free: (0..window as u32).rev().collect(),
                issued: 0,
                inflight: 0,
                source_done: false,
                grant,
                max_boosts,
                seq: 0,
                record,
                results: &mut results,
                tracer,
                ring: tracer.make_ring(),
            };
            loop {
                // admission happens here (not in the completion path)
                // so op chains cannot recurse the coordinator's stack
                coord.pump();
                if coord.inflight == 0 {
                    break;
                }
                match rrx.recv() {
                    Some(reply) => coord.on_reply(reply),
                    // every shard exited early (panic mid-run): stop
                    // pumping; joins below surface the panic
                    None => break,
                }
            }
            tracer.park(coord.ring);

            for tx in &txs {
                let _ = tx.send(ShardMsg::Shutdown);
            }
            drop(txs);
            handles
                .into_iter()
                .map(|h| h.join().expect("live shard panicked"))
                .collect()
        });

        if record {
            results.sort_unstable_by_key(|(idx, _)| *idx);
            self.last_results =
                results.into_iter().map(|(_, sp)| sp).collect();
        } else {
            self.last_results.clear();
        }

        let wall = wall_start.elapsed();
        report.makespan_ns = wall.as_nanos() as u64;
        report.wall_ms = wall.as_secs_f64() * 1e3;
        if report.completed > 0 && wall.as_secs_f64() > 0.0 {
            report.tput_ops_per_s =
                report.completed as f64 / wall.as_secs_f64();
        }
        self.last_run = Some(LiveRunStats {
            shards: shard_stats,
            router: router.snapshot(),
            queues: qstats.iter().map(|q| q.snapshot()).collect(),
            replies: reply_stats.snapshot(),
        });
        self.totals.merge(&report);
        report
    }
}

impl TraversalBackend for LiveBackend {
    fn name(&self) -> &'static str {
        "LIVE"
    }

    fn rack_mut(&mut self) -> &mut Rack {
        &mut self.rack
    }

    fn serves_sharded(&self) -> bool {
        true // one real worker thread per memory node
    }

    fn submit(&mut self, op: &Op) -> [i64; SP_WORDS] {
        self.rack.run_op_functional(op)
    }

    fn serve(
        &mut self,
        ops: &mut dyn FnMut(u64) -> Option<Op>,
        concurrency: usize,
    ) -> ServeReport {
        self.serve_impl(OpSource::Gen(ops), concurrency)
    }

    /// Open-loop batch serving. Ops are issued *by reference* — the
    /// coordinator's slots borrow straight from the slice, so the timed
    /// region measures the engine, not `Op::clone` (stage vectors +
    /// override lists per op). The closed-loop `serve` path still owns
    /// its ops, since a generator must hand them over by value.
    fn serve_batch(&mut self, ops: &[Op], concurrency: usize) -> ServeReport {
        self.serve_impl(OpSource::Batch(ops), concurrency)
    }

    fn metrics(&self) -> BackendMetrics {
        let mut m = BackendMetrics::from_report("LIVE", &self.totals);
        if let Some(run) = &self.last_run {
            m.live_forwards = run.total_forwards();
            m.live_yields = run.total_yields();
            m.live_traps = run.total_traps();
            m.live_drops = run.total_drops();
            m.live_max_queue_depth = run.max_queue_hwm();
        }
        m
    }
}

/// Where the coordinator draws ops from. The batch arm is the
/// `serve_batch` fast path: slots borrow ops straight from the caller's
/// slice instead of cloning each one inside the timed region.
enum OpSource<'a> {
    Gen(&'a mut dyn FnMut(u64) -> Option<Op>),
    Batch(&'a [Op]),
}

/// An admitted op: owned (closed-loop generator) or borrowed from the
/// batch slice (open-loop serving).
enum SlotOp<'a> {
    Owned(Op),
    Borrowed(&'a Op),
}

impl SlotOp<'_> {
    fn get(&self) -> &Op {
        match self {
            SlotOp::Owned(op) => op,
            SlotOp::Borrowed(op) => op,
        }
    }
}

/// One admitted op's dispatcher-side state (the live `OpRun`).
struct Slot<'a> {
    op: SlotOp<'a>,
    op_index: u64,
    stage_idx: usize,
    born: Instant,
    iters_total: u64,
    crossings_total: u32,
    boosts: u32,
    net_bytes: u64,
    /// Causal span counter; synced from each reply's job so emission
    /// resumes where the shard left off (see `obs/README.md`).
    trace_k: u32,
    traced: bool,
}

/// The CPU-node role: admission window, stage chaining, yield grants,
/// and completion accounting. Mirrors the DES's `launch_stage` /
/// `advance_op` state machine over real replies instead of events.
struct Coordinator<'a> {
    txs: &'a [QueueTx<ShardMsg>],
    router: &'a Router,
    report: &'a mut ServeReport,
    source: OpSource<'a>,
    /// One in-flight op per entry, each on its own cache line: replies
    /// complete in arbitrary interleavings, and a store to one hot
    /// slot must not evict the neighbouring in-flight states with it.
    slots: Vec<CachePadded<Option<Slot<'a>>>>,
    free: Vec<u32>,
    issued: u64,
    inflight: usize,
    source_done: bool,
    grant: u32,
    max_boosts: u32,
    seq: u64,
    record: bool,
    results: &'a mut Vec<(u64, [i64; SP_WORDS])>,
    tracer: &'a Tracer,
    /// Coordinator-side span ring (dispatch/boost/finish hops).
    ring: TraceRing,
}

impl<'a> Coordinator<'a> {
    /// Admit new ops until the window is full or the source runs dry.
    fn pump(&mut self) {
        while !self.source_done && self.inflight < self.slots.len() {
            let next: Option<SlotOp<'a>> = match &mut self.source {
                OpSource::Batch(ops) => {
                    // copy the &'a [Op] out so the borrow is 'a, not
                    // the transient &mut self.source reborrow
                    let batch: &'a [Op] = *ops;
                    batch.get(self.issued as usize).map(SlotOp::Borrowed)
                }
                OpSource::Gen(f) => f(self.issued).map(SlotOp::Owned),
            };
            let Some(op) = next else {
                self.source_done = true;
                break;
            };
            let op_index = self.issued;
            self.issued += 1;
            // admission-time shape check, mirroring the DES: malformed
            // ops trap here instead of panicking the coordinator
            if op.get().validate().is_err() {
                self.report.record_admission_trap();
                if self.record {
                    self.results.push((op_index, [0i64; SP_WORDS]));
                }
                continue;
            }
            let token = self
                .free
                .pop()
                .expect("inflight < window implies a free token");
            *self.slots[token as usize] = Some(Slot {
                op,
                op_index,
                stage_idx: 0,
                born: Instant::now(),
                iters_total: 0,
                crossings_total: 0,
                boosts: 0,
                net_bytes: 0,
                trace_k: 0,
                traced: self.tracer.sampled(op_index),
            });
            self.inflight += 1;
            self.dispatch_stage(token, [0i64; SP_WORDS], None);
        }
    }

    /// Resolve and dispatch the current stage of `token` (mirrors the
    /// DES `launch_stage`, including the degenerate start==0 skip).
    fn dispatch_stage(
        &mut self,
        token: u32,
        prev_sp: [i64; SP_WORDS],
        repeat_from: Option<[i64; SP_WORDS]>,
    ) {
        let (start, sp, program, stage_idx) = {
            let slot = self.slots[token as usize].as_ref().unwrap();
            let stage = &slot.op.get().stages[slot.stage_idx];
            let (start, sp) = stage.resolve(&prev_sp, repeat_from);
            let program = (start != 0)
                .then(|| Arc::clone(&stage.iter.program));
            (start, sp, program, slot.stage_idx)
        };
        let Some(program) = program else {
            // degenerate stage (e.g. empty structure): skip forward
            self.advance(token, sp, false);
            return;
        };
        // emitted only for stages that actually dispatch a message, so
        // the DES (which traces at its offload point) stays span-equal
        self.emit(token, SpanKind::Dispatch { stage: stage_idx as u32 });
        let id = RequestId { cpu_node: 0, seq: self.seq };
        self.seq += 1;
        let msg =
            TraversalMsg::request(id, program, start, sp, self.grant);
        self.send(token, msg, false);
    }

    /// Emit one span for `token`'s op into the coordinator ring and
    /// advance the slot's causal counter (bool test when untraced).
    fn emit(&mut self, token: u32, kind: SpanKind) {
        let slot = self.slots[token as usize].as_mut().unwrap();
        if slot.traced {
            self.ring.push(Span {
                op: slot.op_index,
                k: slot.trace_k,
                t_ns: self.tracer.now_ns(),
                kind,
            });
            slot.trace_k += 1;
        }
    }

    /// Wrap a message with its slot's trace identity for the wire.
    fn job(&self, token: u32, msg: TraversalMsg) -> LiveJob {
        let slot = self.slots[token as usize].as_ref().unwrap();
        LiveJob {
            token,
            op: slot.op_index,
            trace_k: slot.trace_k,
            traced: slot.traced,
            // per-run coordinator has no wire clients: attribution
            // rides only through the persistent engine
            timing: None,
            msg,
        }
    }

    /// Resume span emission where the shard left off for this op.
    fn sync_trace(&mut self, job: &LiveJob) {
        if job.traced {
            let slot =
                self.slots[job.token as usize].as_mut().unwrap();
            slot.trace_k = job.trace_k;
        }
    }

    /// Route + enqueue a request; unroutable pointers answer with a
    /// trap (the switch's `Route::Invalid` path).
    fn send(&mut self, token: u32, msg: TraversalMsg, rerouted: bool) {
        match self.router.route(msg.cur_ptr, rerouted) {
            Some(shard) => {
                let job = self.job(token, msg);
                match self.txs[shard as usize].send(ShardMsg::Job(job))
                {
                    Ok(()) => {}
                    Err(ShardMsg::Job(job)) => {
                        // shard gone (teardown race): trap the op so
                        // the run terminates with honest accounting
                        self.account_msg(token, &job.msg);
                        self.report.trapped += 1;
                        self.advance(token, job.msg.sp, true);
                    }
                    Err(ShardMsg::Shutdown) => unreachable!(),
                }
            }
            None => {
                self.account_msg(token, &msg);
                self.report.trapped += 1;
                self.advance(token, msg.sp, true);
            }
        }
    }

    /// Fold a message's accrued work into its slot and the report —
    /// every executed iteration read DRAM, so `mem_bytes` is charged
    /// here exactly as the DES charges it per iteration. Called once
    /// per message lifetime: either on its `Done` reply or on the
    /// path that terminates it early (boost cap, unroutable pointer).
    fn account_msg(&mut self, token: u32, msg: &TraversalMsg) {
        let slot = self.slots[token as usize].as_mut().unwrap();
        slot.iters_total += msg.iters_done as u64;
        slot.crossings_total += msg.node_crossings;
        // dirty windows stream back out after every iteration, exactly
        // as the DES charges them (shared formula: byte parity with
        // the DES is a conformance property)
        self.report.mem_bytes +=
            msg.iters_done as u64 * msg.program.dram_bytes_per_iter();
    }

    fn on_reply(&mut self, reply: Reply) {
        match reply {
            Reply::Done(job) => {
                self.sync_trace(&job);
                let LiveJob { token, msg, .. } = job;
                self.account_msg(token, &msg);
                {
                    let slot =
                        self.slots[token as usize].as_mut().unwrap();
                    let wire = msg.wire_size() as u64;
                    // request + response over the CPU links, plus one
                    // shard-to-shard hop per crossing
                    slot.net_bytes +=
                        wire * 2 + msg.node_crossings as u64 * wire;
                }
                if msg.status == Status::Trap {
                    self.report.trapped += 1;
                }
                self.advance(token, msg.sp, msg.status == Status::Trap);
            }
            Reply::Yield(job) => {
                self.sync_trace(&job);
                let LiveJob { token, mut msg, .. } = job;
                let boosts = {
                    let slot =
                        self.slots[token as usize].as_mut().unwrap();
                    slot.boosts += 1;
                    slot.boosts
                };
                if boosts > self.max_boosts {
                    self.account_msg(token, &msg);
                    self.report.trapped += 1;
                    self.advance(token, msg.sp, true);
                } else {
                    msg.max_iters += self.grant;
                    // grant = the new *total* budget after the boost
                    self.emit(
                        token,
                        SpanKind::Boost { grant: msg.max_iters },
                    );
                    self.send(token, msg, false);
                }
            }
            // PULSE-ACC: the bounce came back to the CPU role; route
            // it onward as a fresh dispatch (the DES counts these as
            // routed requests, not switch reroutes; crossings are
            // already accumulated inside `msg`)
            Reply::Bounced(job) => {
                self.sync_trace(&job);
                let LiveJob { token, msg, .. } = job;
                self.send(token, msg, false);
            }
        }
    }

    /// Stage finished with scratchpad `sp`: repeat, chain, or complete
    /// (mirrors the DES `advance_op`). A `trapped` stage is terminal
    /// for the whole op — repeating it would re-dispatch the same
    /// faulting continuation pointer forever (unbounded
    /// send→advance→dispatch recursion), and later stages would chain
    /// off a poisoned scratchpad.
    fn advance(&mut self, token: u32, sp: [i64; SP_WORDS], trapped: bool) {
        let (repeat, more_stages) = {
            let slot = self.slots[token as usize].as_ref().unwrap();
            let stage = &slot.op.get().stages[slot.stage_idx];
            (
                !trapped && stage.wants_repeat(&sp),
                !trapped
                    && slot.stage_idx + 1 < slot.op.get().stages.len(),
            )
        };
        if repeat {
            self.dispatch_stage(token, sp, Some(sp));
            return;
        }
        if more_stages {
            self.slots[token as usize].as_mut().unwrap().stage_idx += 1;
            self.dispatch_stage(token, sp, None);
            return;
        }
        self.emit(token, SpanKind::Finish { trapped });
        let slot = self.slots[token as usize].take().unwrap();
        let lat = slot.born.elapsed().as_nanos() as u64
            + slot.op.get().cpu_post_ns;
        self.report.completed += 1;
        self.report.latency.record(lat.max(1));
        self.report.crossings.record(slot.crossings_total as u64);
        if slot.crossings_total > 0 {
            self.report.cross_node_requests += 1;
        }
        self.report.total_iters += slot.iters_total;
        self.report.net_bytes += slot.net_bytes;
        if self.record {
            self.results.push((slot.op_index, sp));
        }
        self.free.push(token);
        self.inflight -= 1;
        // the serve loop pumps replacement ops after each reply
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ds::{ForwardList, HashMapDs};
    use crate::rack::{RackConfig, StartAddr};

    fn backend(nodes: usize) -> LiveBackend {
        LiveBackend::new(Rack::new(RackConfig::small(nodes)))
    }

    fn hash_ops(b: &mut LiveBackend, n: u64) -> Vec<Op> {
        let mut m = HashMapDs::build(b.rack_mut(), 64);
        for i in 0..500 {
            m.insert(b.rack_mut(), i, i * 2);
        }
        let prog = m.find_program();
        (0..n)
            .map(|i| {
                let key = (i % 500) as i64;
                let mut sp = [0i64; SP_WORDS];
                sp[0] = key;
                Op::new(prog.clone(), m.bucket_ptr(key), sp)
            })
            .collect()
    }

    #[test]
    fn serves_and_reports_wall_metrics() {
        let mut b = backend(2);
        let ops = hash_ops(&mut b, 200);
        b.record_results(true);
        let rep = b.serve_batch(&ops, 8);
        assert_eq!(rep.completed, 200);
        assert_eq!(rep.trapped, 0);
        assert_eq!(rep.latency.count(), 200);
        assert!(rep.latency.mean() >= 1.0);
        assert!(rep.tput_ops_per_s > 0.0);
        assert!(rep.total_iters >= 200);
        // every op's scratchpad captured, values correct
        let got = b.last_results();
        assert_eq!(got.len(), 200);
        for (i, sp) in got.iter().enumerate() {
            assert_eq!(sp[1], ((i % 500) as i64) * 2, "op {i}");
        }
        let run = b.last_run().unwrap();
        assert_eq!(run.total_iters(), rep.total_iters);
        assert_eq!(run.total_drops(), 0);
        let m = b.metrics();
        assert_eq!(m.name, "LIVE");
        assert_eq!(m.ops, 200);
    }

    #[test]
    fn empty_op_source_is_a_noop() {
        let mut b = backend(1);
        let mut empty = |_: u64| -> Option<Op> { None };
        let rep = b.serve(&mut empty, 4);
        assert_eq!(rep.completed, 0);
        assert_eq!(rep.trapped, 0);
        assert_eq!(b.last_run().unwrap().total_iters(), 0);
    }

    #[test]
    fn unmapped_start_pointer_traps_like_the_switch() {
        let mut b = backend(1);
        let mut ops = hash_ops(&mut b, 1);
        // point the op at unallocated VA space
        ops[0].stages[0].start = StartAddr::Fixed(0xDEAD_0000_0000);
        let rep = b.serve_batch(&ops, 2);
        assert_eq!(rep.completed, 1);
        assert_eq!(rep.trapped, 1);
        assert_eq!(b.last_run().unwrap().router.invalid, 1);
    }

    #[test]
    fn tiny_queue_capacity_clamps_window_but_completes() {
        let mut b = LiveBackend::with_config(
            Rack::new(RackConfig::small(2)),
            LiveConfig { queue_capacity: 2, max_budget_boosts: 4096 },
        );
        let ops = hash_ops(&mut b, 120);
        let rep = b.serve_batch(&ops, 64); // window clamped to 1
        assert_eq!(rep.completed, 120);
        assert_eq!(rep.trapped, 0);
    }

    #[test]
    fn trace_records_causal_hops_and_is_free_when_disabled() {
        use crate::obs::{SpanKind, TraceConfig, TracerStats};

        // disabled (default): serve normally, zero tracer activity
        let mut b = backend(2);
        let ops = hash_ops(&mut b, 50);
        b.serve_batch(&ops, 4);
        assert_eq!(b.tracer_stats(), TracerStats::default());
        assert!(b.take_trace().is_empty());

        // enabled at 1-in-1: every op yields dispatch..finish spans
        b.enable_trace(TraceConfig {
            sample_every: 1,
            seed: 7,
            ring_capacity: 4096,
        });
        b.serve_batch(&ops, 4);
        let trace = b.take_trace();
        let stats = b.tracer_stats();
        assert!(stats.rings_allocated >= 3, "2 shards + coordinator");
        assert_eq!(stats.dropped, 0);
        for op in 0..50u64 {
            let spans: Vec<_> =
                trace.spans.iter().filter(|s| s.op == op).collect();
            assert!(spans.len() >= 3, "op {op}: {spans:?}");
            // causal counter is dense from 0
            for (i, s) in spans.iter().enumerate() {
                assert_eq!(s.k, i as u32, "op {op}");
            }
            assert!(matches!(
                spans[0].kind,
                SpanKind::Dispatch { stage: 0 }
            ));
            assert!(matches!(
                spans[1].kind,
                SpanKind::Visit { .. }
            ));
            assert_eq!(
                spans.last().unwrap().kind,
                SpanKind::Finish { trapped: false }
            );
        }
        // drained once: a second drain is empty
        assert!(b.take_trace().is_empty());
    }

    #[test]
    fn yield_budget_continuation_sums_correctly() {
        let mut cfg = RackConfig::small(1);
        cfg.dispatch.max_iters = 3; // force yields on a 50-hop walk
        let mut b = LiveBackend::new(Rack::new(cfg));
        let mut l = ForwardList::new();
        for i in 1..=50 {
            l.push(b.rack_mut(), i);
        }
        let op = Op::new(l.sum_program(), l.head, [0i64; SP_WORDS]);
        b.record_results(true);
        let rep = b.serve_batch(&[op], 1);
        assert_eq!(rep.completed, 1);
        assert_eq!(rep.trapped, 0);
        assert_eq!(b.last_results()[0][3], (1..=50).sum::<i64>());
        assert!(
            b.last_run().unwrap().total_yields() > 0,
            "3-iter budget over 50 hops must yield"
        );
    }
}
