//! The live engine's dispatch router: the switch's coarse translation
//! (paper §5) replicated as a shared, read-only routing table.
//!
//! The DES models the Tofino switch as an event-processing stage; the
//! live engine replicates exactly its state — the coarse
//! [`RangeMap`] from global VA ranges to owning memory node — as an
//! immutable snapshot every thread consults lock-free. The coordinator
//! routes fresh requests by start pointer (Fig. 6 step 1→2); a shard
//! that discovers a non-local `cur_ptr` routes the bounced request
//! directly to its owner (steps 4→6) without returning to the CPU
//! thread — the in-network distributed-traversal fast path, now as
//! real shard-to-shard queue hops.
//!
//! The snapshot is taken at serve start, so (like the real switch
//! between map updates) allocations made *during* a serve are not
//! visible to routing until the next run. Apps build before serving,
//! matching the DES's publish-then-serve order.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::mem::{GAddr, NodeId, RangeMap};

/// Routing counters (mirrors `switch::SwitchStats` for the live path).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RouterStats {
    /// Successfully routed messages (fresh dispatches + re-routes).
    pub routed: u64,
    /// Bounced requests re-routed shard-to-shard without CPU
    /// involvement — the distributed-traversal fast path.
    pub reroutes: u64,
    /// Pointers that map to no shard (answered with a trap).
    pub invalid: u64,
}

/// Shared coarse translation: VA range -> shard (= memory node).
#[derive(Debug)]
pub struct Router {
    map: RangeMap,
    routed: AtomicU64,
    reroutes: AtomicU64,
    invalid: AtomicU64,
}

impl Router {
    pub fn new(map: RangeMap) -> Self {
        Self {
            map,
            routed: AtomicU64::new(0),
            reroutes: AtomicU64::new(0),
            invalid: AtomicU64::new(0),
        }
    }

    /// Route an address to its owning shard. `rerouted` marks
    /// shard-originated bounces so they are counted separately from
    /// fresh dispatches (the switch's `reroutes` counter).
    pub fn route(&self, addr: GAddr, rerouted: bool) -> Option<NodeId> {
        match self.map.lookup(addr) {
            Some(node) => {
                self.routed.fetch_add(1, Ordering::Relaxed);
                if rerouted {
                    self.reroutes.fetch_add(1, Ordering::Relaxed);
                }
                Some(node)
            }
            None => {
                self.invalid.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    pub fn snapshot(&self) -> RouterStats {
        RouterStats {
            routed: self.routed.load(Ordering::Relaxed),
            reroutes: self.reroutes.load(Ordering::Relaxed),
            invalid: self.invalid.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router() -> Router {
        let mut map = RangeMap::new();
        map.insert(0x1000, 0x1000, 0);
        map.insert(0x2000, 0x1000, 1);
        Router::new(map)
    }

    #[test]
    fn routes_by_owner_and_counts() {
        let r = router();
        assert_eq!(r.route(0x1800, false), Some(0));
        assert_eq!(r.route(0x2000, true), Some(1));
        assert_eq!(r.route(0x9000, false), None);
        let s = r.snapshot();
        assert_eq!(
            s,
            RouterStats { routed: 2, reroutes: 1, invalid: 1 }
        );
    }

    #[test]
    fn shared_across_threads() {
        let r = std::sync::Arc::new(router());
        std::thread::scope(|sc| {
            for _ in 0..4 {
                let r = std::sync::Arc::clone(&r);
                sc.spawn(move || {
                    for _ in 0..1000 {
                        assert_eq!(r.route(0x1008, false), Some(0));
                    }
                });
            }
        });
        assert_eq!(r.snapshot().routed, 4000);
    }
}
