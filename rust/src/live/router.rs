//! The live engine's dispatch router: the switch's coarse translation
//! (paper §5) replicated as a shared, read-only routing table.
//!
//! The DES models the Tofino switch as an event-processing stage; the
//! live engine replicates exactly its state — the coarse
//! [`RangeMap`] from global VA ranges to owning memory node — as an
//! immutable snapshot every thread consults lock-free. The coordinator
//! routes fresh requests by start pointer (Fig. 6 step 1→2); a shard
//! that discovers a non-local `cur_ptr` routes the bounced request
//! directly to its owner (steps 4→6) without returning to the CPU
//! thread — the in-network distributed-traversal fast path, now as
//! real shard-to-shard queue hops.
//!
//! The snapshot is taken at serve start, so (like the real switch
//! between map updates) allocations made *during* a serve are not
//! visible to routing until the next run. Apps build before serving,
//! matching the DES's publish-then-serve order.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::mem::{GAddr, NodeId, RangeMap};
use crate::util::CachePadded;

/// Routing counters (mirrors `switch::SwitchStats` for the live path).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RouterStats {
    /// Successfully routed messages (fresh dispatches + re-routes).
    pub routed: u64,
    /// Bounced requests re-routed shard-to-shard without CPU
    /// involvement — the distributed-traversal fast path.
    pub reroutes: u64,
    /// Pointers that map to no shard (answered with a trap).
    pub invalid: u64,
}

/// Shared coarse translation: VA range -> shard (= memory node).
///
/// The map rides as `Arc<RangeMap>`: building a router from the
/// allocator's published snapshot (and republishing after growth) is
/// a pointer swap, not a deep copy. The counters are bumped from
/// every shard thread concurrently, so each sits on its own cache
/// line — a routed burst on one shard must not invalidate the line a
/// bounce re-route on another shard is writing.
#[derive(Debug)]
pub struct Router {
    map: Arc<RangeMap>,
    routed: CachePadded<AtomicU64>,
    reroutes: CachePadded<AtomicU64>,
    invalid: CachePadded<AtomicU64>,
}

impl Router {
    pub fn new(map: impl Into<Arc<RangeMap>>) -> Self {
        Self {
            map: map.into(),
            routed: CachePadded::new(AtomicU64::new(0)),
            reroutes: CachePadded::new(AtomicU64::new(0)),
            invalid: CachePadded::new(AtomicU64::new(0)),
        }
    }

    /// Route an address to its owning shard. `rerouted` marks
    /// shard-originated bounces so they are counted separately from
    /// fresh dispatches (the switch's `reroutes` counter).
    pub fn route(&self, addr: GAddr, rerouted: bool) -> Option<NodeId> {
        match self.map.lookup(addr) {
            Some(node) => {
                self.routed.fetch_add(1, Ordering::Relaxed);
                if rerouted {
                    self.reroutes.fetch_add(1, Ordering::Relaxed);
                }
                Some(node)
            }
            None => {
                self.invalid.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    pub fn snapshot(&self) -> RouterStats {
        RouterStats {
            routed: self.routed.load(Ordering::Relaxed),
            reroutes: self.reroutes.load(Ordering::Relaxed),
            invalid: self.invalid.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router() -> Router {
        let mut map = RangeMap::new();
        map.insert(0x1000, 0x1000, 0);
        map.insert(0x2000, 0x1000, 1);
        Router::new(map)
    }

    #[test]
    fn routes_by_owner_and_counts() {
        let r = router();
        assert_eq!(r.route(0x1800, false), Some(0));
        assert_eq!(r.route(0x2000, true), Some(1));
        assert_eq!(r.route(0x9000, false), None);
        let s = r.snapshot();
        assert_eq!(
            s,
            RouterStats { routed: 2, reroutes: 1, invalid: 1 }
        );
    }

    #[test]
    fn shared_across_threads() {
        let r = std::sync::Arc::new(router());
        std::thread::scope(|sc| {
            for _ in 0..4 {
                let r = std::sync::Arc::clone(&r);
                sc.spawn(move || {
                    for _ in 0..1000 {
                        assert_eq!(r.route(0x1008, false), Some(0));
                    }
                });
            }
        });
        assert_eq!(r.snapshot().routed, 4000);
    }

    // --- RangeMap snapshot boundary conditions (the k-hop graph is the
    // --- first workload that lands pointers on arbitrary range edges)

    #[test]
    fn pointers_exactly_on_shard_range_edges() {
        let mut map = RangeMap::new();
        map.insert(0x1000, 0x1000, 0);
        map.insert(0x2000, 0x1000, 1); // adjacent: no gap byte
        map.insert(0x4000, 0x1000, 0); // gap before this one
        let r = Router::new(map);
        // first/last byte of every range, both sides of every edge
        assert_eq!(r.route(0x0FFF, false), None);
        assert_eq!(r.route(0x1000, false), Some(0)); // range start
        assert_eq!(r.route(0x1FFF, false), Some(0)); // range last byte
        assert_eq!(r.route(0x2000, false), Some(1)); // adjacent handoff
        assert_eq!(r.route(0x2FFF, false), Some(1));
        assert_eq!(r.route(0x3000, false), None); // gap start
        assert_eq!(r.route(0x3FFF, false), None); // gap last byte
        assert_eq!(r.route(0x4000, false), Some(0));
        assert_eq!(r.route(0x4FFF, false), Some(0));
        assert_eq!(r.route(0x5000, false), None); // past the end
        let s = r.snapshot();
        assert_eq!(s.routed, 6);
        assert_eq!(s.invalid, 4);
    }

    #[test]
    fn single_shard_map_owns_everything_in_range() {
        let mut map = RangeMap::new();
        map.insert(0x10_000, 0x10_000, 0);
        map.insert(0x20_000, 0x10_000, 0); // coalesces (same node)
        let r = Router::new(map);
        for addr in
            [0x10_000u64, 0x17_FF8, 0x1F_FFF, 0x20_000, 0x2F_FFF]
        {
            assert_eq!(r.route(addr, false), Some(0), "addr {addr:#x}");
        }
        assert_eq!(r.route(0x0F_FFF, false), None);
        assert_eq!(r.route(0x30_000, false), None);
        assert_eq!(r.snapshot().reroutes, 0);
    }

    #[test]
    fn remap_after_restart_sees_new_slabs_old_snapshot_does_not() {
        use crate::rack::{Rack, RackConfig};
        // serve-time snapshot semantics: a router built before an
        // allocation keeps answering from the stale map (like the real
        // switch between map pushes); the next serve's fresh snapshot
        // must route the new slab — and start its counters at zero
        let mut rack = Rack::new(RackConfig {
            nodes: 2,
            node_capacity: 8 << 20,
            granularity: 4096,
            ..Default::default()
        });
        let a0 = rack.alloc(64);
        let old = Router::new(rack.alloc.publish_map());
        assert_eq!(old.route(a0, false), rack.alloc.owner(a0));
        // force fresh slabs (restart boundary)
        let grown: Vec<_> = (0..8).map(|_| rack.alloc(4096)).collect();
        let fresh_addr = *grown.last().unwrap();
        assert_eq!(
            old.route(fresh_addr, false),
            None,
            "stale snapshot must not route post-snapshot slabs"
        );
        let fresh = Router::new(rack.alloc.publish_map());
        assert_eq!(fresh.route(fresh_addr, false), rack.alloc.owner(fresh_addr));
        assert_eq!(fresh.route(a0, false), rack.alloc.owner(a0));
        // per-run counters reset with the snapshot (restart semantics)
        let s = fresh.snapshot();
        assert_eq!((s.routed, s.invalid), (2, 0));
        assert!(old.snapshot().invalid >= 1);
    }
}
