//! Bounded MPSC request queues for the live engine.
//!
//! A thin instrumented wrapper over `std::sync::mpsc::sync_channel`:
//! each shard owns one receiver; the coordinator and every peer shard
//! hold cloned senders (requests arrive from the dispatcher *and* as
//! cross-shard bounces, paper Fig. 6 steps 1 and 4). The wrapper adds
//! the occupancy counters the engine's metrics report (depth =
//! pushed - popped, full-queue backpressure events) without touching
//! the transfer fast path.
//!
//! Capacity discipline (the engine's no-deadlock invariant): every
//! in-flight op is exactly one message somewhere in the system, so as
//! long as each queue's capacity is at least the admitted window + 1
//! (the +1 absorbs the shutdown marker), no `send` can block on a full
//! queue and cross-shard forwarding cannot form a blocking cycle.
//! `LiveBackend` sizes queues that way by default and clamps the
//! window when a caller picks a smaller capacity.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;

use crate::util::CachePadded;

/// Shared occupancy counters of one queue (lock-free, relaxed: the
/// counts are metrics, not synchronization).
///
/// Producer-touched counters (`pushed`, `full_blocks`, `rejects` —
/// bumped by the coordinator and every forwarding peer shard) and the
/// consumer-touched one (`popped` — bumped only by the owning shard)
/// live on separate cache lines: without the padding every pop would
/// invalidate the line the producers are writing and vice versa —
/// false sharing on the hottest cross-thread path in the engine.
#[derive(Debug, Default)]
pub struct QueueStats {
    /// Producer side (send/try_send), one line.
    pushed: AtomicU64,
    full_blocks: AtomicU64,
    rejects: AtomicU64,
    /// High-water mark of the observed depth, updated at push time
    /// (producer side — stays on the producer line).
    hwm: AtomicU64,
    /// Consumer side (recv/try_recv), its own line.
    popped: CachePadded<AtomicU64>,
    capacity: u64,
}

/// Point-in-time view of a queue's counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueueSnapshot {
    pub capacity: u64,
    pub pushed: u64,
    pub popped: u64,
    /// Times a sender found the queue full and had to block.
    pub full_blocks: u64,
    /// Times a `try_send` found the queue full and gave up — the
    /// explicit-backpressure path (the serving tier answers BUSY
    /// instead of blocking a socket reader on engine capacity).
    pub rejects: u64,
    /// Deepest occupancy any push observed (max queue occupancy over
    /// the run; surfaced through `LiveRunStats` and the registry).
    pub hwm: u64,
}

impl QueueSnapshot {
    /// Messages currently buffered (or in the receiver's hands).
    pub fn depth(&self) -> u64 {
        self.pushed.saturating_sub(self.popped)
    }
}

impl QueueStats {
    pub fn snapshot(&self) -> QueueSnapshot {
        QueueSnapshot {
            capacity: self.capacity,
            pushed: self.pushed.load(Ordering::Relaxed),
            popped: self.popped.load(Ordering::Relaxed),
            full_blocks: self.full_blocks.load(Ordering::Relaxed),
            rejects: self.rejects.load(Ordering::Relaxed),
            hwm: self.hwm.load(Ordering::Relaxed),
        }
    }

    /// Count a successful push and fold the observed depth into the
    /// high-water mark (two relaxed RMWs + one load, producer line).
    ///
    /// The folded depth is clamped to `capacity`: `pushed` is bumped
    /// *before* `popped` is loaded, and both are relaxed, so under
    /// producer/consumer contention the `popped` value can be stale by
    /// however many pops raced in between — which let the unclamped
    /// difference exceed the true occupancy and even the queue's
    /// capacity, reporting a physically impossible high-water mark.
    /// True occupancy never exceeds capacity (the channel is bounded),
    /// so the clamp only discards the race artifact, never a real
    /// observation.
    #[inline]
    fn note_push(&self) {
        let pushed = self.pushed.fetch_add(1, Ordering::Relaxed) + 1;
        let popped = self.popped.load(Ordering::Relaxed);
        let depth = pushed.saturating_sub(popped).min(self.capacity);
        self.hwm.fetch_max(depth, Ordering::Relaxed);
    }
}

/// Why a [`QueueTx::try_send`] did not enqueue; carries the value back.
#[derive(Debug)]
pub enum TrySend<T> {
    /// Queue at capacity right now — caller should shed load (BUSY).
    Full(T),
    /// Receiver gone — the consumer has exited for good.
    Disconnected(T),
}

/// Sending half; clone one per producer.
#[derive(Debug)]
pub struct QueueTx<T> {
    tx: SyncSender<T>,
    stats: Arc<QueueStats>,
}

// Manual impl: `T` need not be `Clone` for the handle to be.
impl<T> Clone for QueueTx<T> {
    fn clone(&self) -> Self {
        Self { tx: self.tx.clone(), stats: Arc::clone(&self.stats) }
    }
}

/// Receiving half; owned by exactly one consumer.
#[derive(Debug)]
pub struct QueueRx<T> {
    rx: Receiver<T>,
    stats: Arc<QueueStats>,
}

/// Create a bounded MPSC queue of the given capacity (>= 1).
pub fn bounded<T>(capacity: usize) -> (QueueTx<T>, QueueRx<T>) {
    let capacity = capacity.max(1);
    let (tx, rx) = sync_channel(capacity);
    let stats = Arc::new(QueueStats {
        capacity: capacity as u64,
        ..QueueStats::default()
    });
    (
        QueueTx { tx, stats: Arc::clone(&stats) },
        QueueRx { rx, stats },
    )
}

impl<T> QueueTx<T> {
    /// Send, blocking while the queue is full. Returns the value back
    /// when the receiver is gone (shard exited), so the caller can
    /// account for the drop instead of panicking.
    pub fn send(&self, v: T) -> Result<(), T> {
        match self.tx.try_send(v) {
            Ok(()) => {
                self.stats.note_push();
                Ok(())
            }
            Err(TrySendError::Full(v)) => {
                self.stats.full_blocks.fetch_add(1, Ordering::Relaxed);
                match self.tx.send(v) {
                    Ok(()) => {
                        self.stats.note_push();
                        Ok(())
                    }
                    Err(e) => Err(e.0),
                }
            }
            Err(TrySendError::Disconnected(v)) => Err(v),
        }
    }

    /// Non-blocking send: enqueue if there is room *right now*,
    /// otherwise hand the value back. This is the admission edge of
    /// the serving tier's backpressure discipline — a full queue is an
    /// explicit signal (BUSY) to the caller, never a hidden stall.
    pub fn try_send(&self, v: T) -> Result<(), TrySend<T>> {
        match self.tx.try_send(v) {
            Ok(()) => {
                self.stats.note_push();
                Ok(())
            }
            Err(TrySendError::Full(v)) => {
                self.stats.rejects.fetch_add(1, Ordering::Relaxed);
                Err(TrySend::Full(v))
            }
            Err(TrySendError::Disconnected(v)) => {
                Err(TrySend::Disconnected(v))
            }
        }
    }

    /// Handle to the shared counters (survives the queue itself).
    pub fn stats_handle(&self) -> Arc<QueueStats> {
        Arc::clone(&self.stats)
    }
}

impl<T> QueueRx<T> {
    /// Receive, blocking until a message arrives. `None` once every
    /// sender is gone and the queue is drained.
    pub fn recv(&self) -> Option<T> {
        match self.rx.recv() {
            Ok(v) => {
                self.stats.popped.fetch_add(1, Ordering::Relaxed);
                Some(v)
            }
            Err(_) => None,
        }
    }

    /// Non-blocking receive (`None` = currently empty OR disconnected;
    /// used by the shard drain loop after a shutdown marker).
    pub fn try_recv(&self) -> Option<T> {
        match self.rx.try_recv() {
            Ok(v) => {
                self.stats.popped.fetch_add(1, Ordering::Relaxed);
                Some(v)
            }
            Err(_) => None,
        }
    }

    pub fn stats_handle(&self) -> Arc<QueueStats> {
        Arc::clone(&self.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_within_single_producer() {
        let (tx, rx) = bounded::<u32>(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        for want in 0..5 {
            assert_eq!(rx.recv(), Some(want));
        }
        let s = tx.stats_handle().snapshot();
        assert_eq!(s.pushed, 5);
        assert_eq!(s.popped, 5);
        assert_eq!(s.depth(), 0);
    }

    #[test]
    fn try_send_rejects_when_full_and_counts() {
        let (tx, rx) = bounded::<u32>(2);
        tx.try_send(1).unwrap();
        tx.try_send(2).unwrap();
        match tx.try_send(3) {
            Err(TrySend::Full(3)) => {}
            other => panic!("expected Full(3), got {other:?}"),
        }
        let s = tx.stats_handle().snapshot();
        assert_eq!(s.pushed, 2);
        assert_eq!(s.rejects, 1);
        assert_eq!(s.full_blocks, 0, "try_send never blocks");
        // room frees up -> accepted again
        assert_eq!(rx.recv(), Some(1));
        tx.try_send(3).unwrap();
        drop(rx);
        match tx.try_send(4) {
            Err(TrySend::Disconnected(4)) => {}
            other => panic!("expected Disconnected(4), got {other:?}"),
        }
    }

    #[test]
    fn send_returns_value_when_receiver_gone() {
        let (tx, rx) = bounded::<u32>(2);
        drop(rx);
        assert_eq!(tx.send(7), Err(7));
    }

    #[test]
    fn recv_drains_then_reports_disconnect() {
        let (tx, rx) = bounded::<u32>(4);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn hwm_never_exceeds_capacity_under_contention() {
        // regression for the note_push race: `pushed` is incremented
        // before `popped` is loaded, so a consumer racing ahead made
        // the folded depth exceed true occupancy (and capacity). Four
        // producers against one fast consumer on a tiny queue hit the
        // stale-popped window constantly; the clamp keeps hwm honest.
        const CAP: usize = 4;
        let (tx, rx) = bounded::<u64>(CAP);
        let stats = rx.stats_handle();
        let total = std::sync::atomic::AtomicU64::new(0);
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let tx = tx.clone();
                s.spawn(move || {
                    for i in 0..10_000u64 {
                        tx.send(t * 100_000 + i).unwrap();
                    }
                });
            }
            drop(tx);
            let total = &total;
            s.spawn(move || {
                while rx.recv().is_some() {
                    total.fetch_add(1, Ordering::Relaxed);
                }
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 40_000);
        let s = stats.snapshot();
        assert_eq!(s.pushed, 40_000);
        assert_eq!(s.popped, 40_000);
        assert!(
            s.hwm <= CAP as u64,
            "hwm {} exceeds capacity {CAP}: the stale-popped race \
             leaked through the clamp",
            s.hwm
        );
        assert!(s.hwm >= 1, "40k sends never observed any occupancy");
    }

    #[test]
    fn full_queue_blocks_sender_until_consumed() {
        let (tx, rx) = bounded::<u32>(1);
        let h = std::thread::spawn(move || {
            // second send must block until the consumer drains
            tx.send(1).unwrap();
            tx.send(2).unwrap();
            tx.stats_handle().snapshot()
        });
        // give the producer a chance to hit the full queue
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        let s = h.join().unwrap();
        assert_eq!(s.pushed, 2);
        assert!(s.full_blocks >= 1, "producer never saw the queue full");
    }
}
