//! Shard worker: one OS thread per memory node, owning that node's
//! [`Accelerator`] (DRAM region + TCAM range table + logic engine).
//!
//! The worker loop is the live realization of the accelerator's visit
//! cycle (paper §4.2 + Fig. 6): pop a request, execute iterations
//! against local DRAM until the traversal finishes, yields its budget,
//! or follows a pointer off-shard. Non-local pointers are forwarded
//! *directly* to the owning shard's queue when in-network routing is
//! on (Fig. 6 steps 4→6 — the half-RTT the paper saves); with it off
//! (PULSE-ACC mode) the bounce returns to the dispatcher thread, which
//! re-routes it — the extra hop Fig. 9 charges PULSE-ACC for.
//!
//! Tracing: a sampled op's `LiveJob` carries its admission index and a
//! causal span counter (`trace_k`); the worker emits `Visit` (and
//! `Forward`/`Bounce`) spans into its private ring and the counter
//! travels onward with the job, so the drained spans sort back into
//! hop order no matter which shard's ring they landed in (see
//! `obs/README.md`). Untraced jobs pay one bool test per hop.
//!
//! Shutdown protocol: the dispatcher sends one `Shutdown` marker per
//! shard only after every op has completed, so the marker is always
//! the logical tail of the queue; the worker still switches to a
//! drain-then-exit loop (processing any stragglers) so teardown is
//! safe even if a future caller relaxes that ordering.

use std::sync::Arc;
use std::time::Instant;

use crate::accel::{Accelerator, VisitEnd};
use crate::isa::Status;
use crate::net::{MsgKind, TraversalMsg};
use crate::obs::{Span, SpanKind, TraceRing, Tracer};

use super::metrics::ShardStats;
use super::queue::{QueueRx, QueueTx};
use super::router::Router;

/// Phase-sliced latency accounting that travels with a job when the
/// submitter asked for attribution (`Submission::t0` set). `enq` is
/// re-stamped at every queue push; the pop-side delta lands in
/// `queue_ns` on the first visit (admission → first pop, engine inbox
/// wait included) and in `transit_ns` on every later hop
/// (forward/bounce/boost legs). `exec_ns` accumulates measured
/// `Accelerator::visit` durations. All slices are disjoint by
/// construction, so `queue + exec + transit <= wall`.
#[derive(Debug, Clone, Copy)]
pub(crate) struct JobTiming {
    /// Last enqueue stamp (admission t0 before the first pop).
    pub enq: Instant,
    /// Admission → first shard pop.
    pub queue_ns: u64,
    /// Sum of measured visit durations.
    pub exec_ns: u64,
    /// Inter-hop transit after the first pop.
    pub transit_ns: u64,
    /// Shard pops this traversal made.
    pub visits: u32,
}

impl JobTiming {
    pub fn start(t0: Instant) -> Self {
        Self { enq: t0, queue_ns: 0, exec_ns: 0, transit_ns: 0, visits: 0 }
    }
}

/// One in-flight traversal: the dispatcher-side slot token + the
/// self-contained request/continuation message (same wire format on
/// every hop, paper §5) + the trace identity that travels with it.
#[derive(Debug)]
pub(crate) struct LiveJob {
    pub token: u32,
    /// Admission index of the op (trace identity; 0 when untraced).
    pub op: u64,
    /// Causal span counter: the next span this traversal emits,
    /// anywhere, uses this k and increments it.
    pub trace_k: u32,
    /// Whether this op was sampled for tracing.
    pub traced: bool,
    /// Phase accounting; `None` (the default) costs one test per hop.
    pub timing: Option<JobTiming>,
    pub msg: TraversalMsg,
}

impl LiveJob {
    /// An untraced job (the default when tracing is disabled).
    pub fn untraced(token: u32, msg: TraversalMsg) -> Self {
        Self { token, op: 0, trace_k: 0, traced: false, timing: None, msg }
    }

    /// Emit one span for this job into `ring` and advance its causal
    /// counter. No-op (one bool test) when the job is untraced.
    #[inline]
    pub fn emit(&mut self, ring: &mut TraceRing, t_ns: u64, kind: SpanKind) {
        if self.traced {
            ring.push(Span { op: self.op, k: self.trace_k, t_ns, kind });
            self.trace_k += 1;
        }
    }
}

/// Messages a shard's request queue carries.
#[derive(Debug)]
pub(crate) enum ShardMsg {
    Job(LiveJob),
    /// Teardown marker; switches the worker to drain-then-exit.
    Shutdown,
}

/// Messages back to the dispatcher thread. Each carries the whole
/// [`LiveJob`] so the trace identity (op, k) survives the round trip
/// and the dispatcher resumes emission where the shard left off.
#[derive(Debug)]
pub(crate) enum Reply {
    /// Traversal finished (`msg.status` is `Return` or `Trap`).
    Done(LiveJob),
    /// Iteration budget exhausted; dispatcher grants more and
    /// re-dispatches (paper §3 max-iteration bound).
    Yield(LiveJob),
    /// PULSE-ACC mode only: non-local pointer returned to the
    /// dispatcher for re-routing instead of hopping shard-to-shard.
    Bounced(LiveJob),
}

/// Worker body; returns its counters when the thread joins (its trace
/// ring is parked on `tracer` first).
///
/// Generic over the reply queue's message type so the same worker
/// serves both consumers: the per-run `LiveBackend` coordinator
/// (`R = Reply`) and the persistent [`super::engine`] dispatcher,
/// whose single inbox multiplexes replies with foreign-thread
/// submissions (`R = EngineMsg`, via `From<Reply>`).
pub(crate) fn run_shard<R: From<Reply>>(
    accel: &mut Accelerator,
    rx: QueueRx<ShardMsg>,
    peers: Vec<QueueTx<ShardMsg>>,
    replies: QueueTx<R>,
    router: Arc<Router>,
    in_network: bool,
    tracer: &Tracer,
) -> ShardStats {
    let mut stats = ShardStats::default();
    // preallocated outside the serving loop; zero-capacity when
    // tracing is disabled (no allocation, pushes never happen)
    let mut ring = tracer.make_ring();
    let mut draining = false;
    loop {
        let m = if draining {
            match rx.try_recv() {
                Some(m) => m,
                None => break,
            }
        } else {
            match rx.recv() {
                Some(m) => m,
                None => break,
            }
        };
        let mut job = match m {
            ShardMsg::Shutdown => {
                draining = true;
                continue;
            }
            ShardMsg::Job(job) => job,
        };
        stats.jobs += 1;
        // attribution: charge the pop-side wait to queue (first pop)
        // or transit (later hops), then time the visit itself
        let exec_start = job.timing.as_mut().map(|t| {
            let now = Instant::now();
            let d = now.saturating_duration_since(t.enq).as_nanos() as u64;
            if t.visits == 0 {
                t.queue_ns += d;
            } else {
                t.transit_ns += d;
            }
            t.visits += 1;
            now
        });
        let out = accel.visit(&mut job.msg);
        if let (Some(t), Some(s)) = (job.timing.as_mut(), exec_start) {
            t.exec_ns += s.elapsed().as_nanos() as u64;
            // re-stamp for whichever egress leg follows (forward,
            // bounce, or the reply back to the dispatcher)
            t.enq = Instant::now();
        }
        stats.iters += out.iters as u64;
        if job.traced {
            let dram = out.iters as u64
                * job.msg.program.dram_bytes_per_iter();
            job.emit(
                &mut ring,
                tracer.now_ns(),
                SpanKind::Visit {
                    shard: accel.node as u32,
                    iters: out.iters,
                    dram_bytes: dram,
                },
            );
        }
        match out.end {
            VisitEnd::Done(st) => {
                if st == Status::Trap {
                    stats.traps += 1;
                }
                job.msg.status = st;
                job.msg.kind = MsgKind::Response;
                send_reply(&replies, Reply::Done(job), &mut stats);
            }
            VisitEnd::Yield => {
                stats.yields += 1;
                send_reply(&replies, Reply::Yield(job), &mut stats);
            }
            VisitEnd::NotLocal => {
                if !in_network {
                    job.emit(&mut ring, tracer.now_ns(), SpanKind::Bounce);
                    send_reply(&replies, Reply::Bounced(job), &mut stats);
                    continue;
                }
                match router.route(job.msg.cur_ptr, true) {
                    // Routing back to ourselves would spin forever (the
                    // fine table already said "not here"); the DES has
                    // no such pointer either — trap defensively.
                    Some(next) if next != accel.node => {
                        stats.forwards += 1;
                        job.emit(
                            &mut ring,
                            tracer.now_ns(),
                            SpanKind::Forward { to: next as u32 },
                        );
                        if let Err(ShardMsg::Job(job)) =
                            peers[next as usize].send(ShardMsg::Job(job))
                        {
                            // peer already tore down: report the loss
                            // upstream as a trap so the op terminates
                            stats.drops += 1;
                            answer_trap(&replies, job, &mut stats);
                        }
                    }
                    _ => {
                        stats.traps += 1;
                        answer_trap(&replies, job, &mut stats);
                    }
                }
            }
        }
    }
    tracer.park(ring);
    stats
}

fn answer_trap<R: From<Reply>>(
    replies: &QueueTx<R>,
    mut job: LiveJob,
    stats: &mut ShardStats,
) {
    job.msg.status = Status::Trap;
    job.msg.kind = MsgKind::Response;
    send_reply(replies, Reply::Done(job), stats);
}

fn send_reply<R: From<Reply>>(
    replies: &QueueTx<R>,
    reply: Reply,
    stats: &mut ShardStats,
) {
    if replies.send(reply.into()).is_err() {
        // dispatcher already gone (teardown after an early bail-out)
        stats.drops += 1;
    }
}
