//! Shard worker: one OS thread per memory node, owning that node's
//! [`Accelerator`] (DRAM region + TCAM range table + logic engine).
//!
//! The worker loop is the live realization of the accelerator's visit
//! cycle (paper §4.2 + Fig. 6): pop a request, execute iterations
//! against local DRAM until the traversal finishes, yields its budget,
//! or follows a pointer off-shard. Non-local pointers are forwarded
//! *directly* to the owning shard's queue when in-network routing is
//! on (Fig. 6 steps 4→6 — the half-RTT the paper saves); with it off
//! (PULSE-ACC mode) the bounce returns to the dispatcher thread, which
//! re-routes it — the extra hop Fig. 9 charges PULSE-ACC for.
//!
//! Shutdown protocol: the dispatcher sends one `Shutdown` marker per
//! shard only after every op has completed, so the marker is always
//! the logical tail of the queue; the worker still switches to a
//! drain-then-exit loop (processing any stragglers) so teardown is
//! safe even if a future caller relaxes that ordering.

use std::sync::Arc;

use crate::accel::{Accelerator, VisitEnd};
use crate::isa::Status;
use crate::net::{MsgKind, TraversalMsg};

use super::metrics::ShardStats;
use super::queue::{QueueRx, QueueTx};
use super::router::Router;

/// One in-flight traversal: the dispatcher-side slot token + the
/// self-contained request/continuation message (same wire format on
/// every hop, paper §5).
#[derive(Debug)]
pub(crate) struct LiveJob {
    pub token: u32,
    pub msg: TraversalMsg,
}

/// Messages a shard's request queue carries.
#[derive(Debug)]
pub(crate) enum ShardMsg {
    Job(LiveJob),
    /// Teardown marker; switches the worker to drain-then-exit.
    Shutdown,
}

/// Messages back to the dispatcher thread.
#[derive(Debug)]
pub(crate) enum Reply {
    /// Traversal finished (`msg.status` is `Return` or `Trap`).
    Done { token: u32, msg: TraversalMsg },
    /// Iteration budget exhausted; dispatcher grants more and
    /// re-dispatches (paper §3 max-iteration bound).
    Yield { token: u32, msg: TraversalMsg },
    /// PULSE-ACC mode only: non-local pointer returned to the
    /// dispatcher for re-routing instead of hopping shard-to-shard.
    Bounced { token: u32, msg: TraversalMsg },
}

/// Worker body; returns its counters when the thread joins.
///
/// Generic over the reply queue's message type so the same worker
/// serves both consumers: the per-run `LiveBackend` coordinator
/// (`R = Reply`) and the persistent [`super::engine`] dispatcher,
/// whose single inbox multiplexes replies with foreign-thread
/// submissions (`R = EngineMsg`, via `From<Reply>`).
pub(crate) fn run_shard<R: From<Reply>>(
    accel: &mut Accelerator,
    rx: QueueRx<ShardMsg>,
    peers: Vec<QueueTx<ShardMsg>>,
    replies: QueueTx<R>,
    router: Arc<Router>,
    in_network: bool,
) -> ShardStats {
    let mut stats = ShardStats::default();
    let mut draining = false;
    loop {
        let m = if draining {
            match rx.try_recv() {
                Some(m) => m,
                None => break,
            }
        } else {
            match rx.recv() {
                Some(m) => m,
                None => break,
            }
        };
        let mut job = match m {
            ShardMsg::Shutdown => {
                draining = true;
                continue;
            }
            ShardMsg::Job(job) => job,
        };
        stats.jobs += 1;
        let out = accel.visit(&mut job.msg);
        stats.iters += out.iters as u64;
        match out.end {
            VisitEnd::Done(st) => {
                if st == Status::Trap {
                    stats.traps += 1;
                }
                job.msg.status = st;
                job.msg.kind = MsgKind::Response;
                send_reply(&replies, Reply::Done { token: job.token, msg: job.msg }, &mut stats);
            }
            VisitEnd::Yield => {
                stats.yields += 1;
                send_reply(&replies, Reply::Yield { token: job.token, msg: job.msg }, &mut stats);
            }
            VisitEnd::NotLocal => {
                if !in_network {
                    send_reply(
                        &replies,
                        Reply::Bounced { token: job.token, msg: job.msg },
                        &mut stats,
                    );
                    continue;
                }
                match router.route(job.msg.cur_ptr, true) {
                    // Routing back to ourselves would spin forever (the
                    // fine table already said "not here"); the DES has
                    // no such pointer either — trap defensively.
                    Some(next) if next != accel.node => {
                        stats.forwards += 1;
                        let token = job.token;
                        if let Err(ShardMsg::Job(job)) =
                            peers[next as usize].send(ShardMsg::Job(job))
                        {
                            // peer already tore down: report the loss
                            // upstream as a trap so the op terminates
                            stats.drops += 1;
                            answer_trap(&replies, token, job.msg, &mut stats);
                        }
                    }
                    _ => {
                        stats.traps += 1;
                        let token = job.token;
                        answer_trap(&replies, token, job.msg, &mut stats);
                    }
                }
            }
        }
    }
    stats
}

fn answer_trap<R: From<Reply>>(
    replies: &QueueTx<R>,
    token: u32,
    mut msg: TraversalMsg,
    stats: &mut ShardStats,
) {
    msg.status = Status::Trap;
    msg.kind = MsgKind::Response;
    send_reply(replies, Reply::Done { token, msg }, stats);
}

fn send_reply<R: From<Reply>>(
    replies: &QueueTx<R>,
    reply: Reply,
    stats: &mut ShardStats,
) {
    if replies.send(reply.into()).is_err() {
        // dispatcher already gone (teardown after an early bail-out)
        stats.drops += 1;
    }
}
