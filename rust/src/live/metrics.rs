//! Per-run metrics of the live engine: shard counters, router
//! counters, and queue occupancy, assembled after the worker threads
//! join. Wall-clock latency/throughput live in the ordinary
//! [`crate::rack::ServeReport`]; this is the engine-internal view
//! (who executed what, how traffic moved) that the DES gets for free
//! from its event log.

use crate::live::queue::QueueSnapshot;
use crate::live::router::RouterStats;
use crate::util::json::Json;

/// Counters of one shard worker (returned by the thread on join).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardStats {
    /// Jobs pulled off the request queue (visits, incl. re-entries).
    pub jobs: u64,
    /// Iterations executed on this shard's accelerator.
    pub iters: u64,
    /// Bounced requests forwarded directly to a peer shard.
    pub forwards: u64,
    /// Budget-exhaustion yields sent back to the dispatcher.
    pub yields: u64,
    /// Traversals that ended in a trap on this shard.
    pub traps: u64,
    /// Forwards lost because the peer had already exited (only
    /// possible during teardown; 0 in a healthy run).
    pub drops: u64,
}

/// Everything the engine observed during one serve run.
#[derive(Debug, Clone, Default)]
pub struct LiveRunStats {
    pub shards: Vec<ShardStats>,
    pub router: RouterStats,
    /// Per-shard request-queue counters.
    pub queues: Vec<QueueSnapshot>,
    /// The shared reply queue back to the dispatcher.
    pub replies: QueueSnapshot,
}

impl LiveRunStats {
    pub fn total_iters(&self) -> u64 {
        self.shards.iter().map(|s| s.iters).sum()
    }

    pub fn total_forwards(&self) -> u64 {
        self.shards.iter().map(|s| s.forwards).sum()
    }

    pub fn total_yields(&self) -> u64 {
        self.shards.iter().map(|s| s.yields).sum()
    }

    pub fn total_drops(&self) -> u64 {
        self.shards.iter().map(|s| s.drops).sum()
    }

    pub fn total_traps(&self) -> u64 {
        self.shards.iter().map(|s| s.traps).sum()
    }

    /// Deepest occupancy any request queue reached during the run
    /// (max over per-shard high-water marks; the backpressure signal
    /// the operator report surfaces).
    pub fn max_queue_hwm(&self) -> u64 {
        self.queues.iter().map(|q| q.hwm).max().unwrap_or(0)
    }

    /// Load-balance skew: busiest shard's iterations over the mean
    /// (1.0 = perfectly even). 0.0 for an empty run.
    pub fn iter_skew(&self) -> f64 {
        let total = self.total_iters();
        if total == 0 || self.shards.is_empty() {
            return 0.0;
        }
        let mean = total as f64 / self.shards.len() as f64;
        let max = self.shards.iter().map(|s| s.iters).max().unwrap_or(0);
        max as f64 / mean
    }

    pub fn summary(&self) -> String {
        format!(
            "shards={} iters={} forwards={} yields={} skew={:.2} \
             reroutes={} invalid={}",
            self.shards.len(),
            self.total_iters(),
            self.total_forwards(),
            self.total_yields(),
            self.iter_skew(),
            self.router.reroutes,
            self.router.invalid,
        )
    }

    /// Machine-readable form for the bench harness.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("shards", self.shards.len())
            .set("total_iters", self.total_iters())
            .set("total_forwards", self.total_forwards())
            .set("total_yields", self.total_yields())
            .set("total_drops", self.total_drops())
            .set("total_traps", self.total_traps())
            .set("max_queue_hwm", self.max_queue_hwm())
            .set("iter_skew", self.iter_skew())
            .set("router_routed", self.router.routed)
            .set("router_reroutes", self.router.reroutes)
            .set("router_invalid", self.router.invalid);
        let missing = QueueSnapshot::default();
        let per_shard: Vec<Json> = self
            .shards
            .iter()
            .zip(self.queues.iter().chain(std::iter::repeat(&missing)))
            .map(|(s, q)| {
                let mut o = Json::obj();
                o.set("jobs", s.jobs)
                    .set("iters", s.iters)
                    .set("forwards", s.forwards)
                    .set("yields", s.yields)
                    .set("traps", s.traps)
                    .set("queue_pushed", q.pushed)
                    .set("queue_full_blocks", q.full_blocks)
                    .set("queue_hwm", q.hwm);
                o
            })
            .collect();
        j.set("per_shard", per_shard);
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_and_skew() {
        let s = LiveRunStats {
            shards: vec![
                ShardStats { jobs: 10, iters: 300, ..Default::default() },
                ShardStats { jobs: 10, iters: 100, ..Default::default() },
            ],
            ..Default::default()
        };
        assert_eq!(s.total_iters(), 400);
        assert!((s.iter_skew() - 1.5).abs() < 1e-9);
        assert_eq!(s.total_drops(), 0);
    }

    #[test]
    fn empty_run_is_well_defined() {
        let s = LiveRunStats::default();
        assert_eq!(s.total_iters(), 0);
        assert_eq!(s.iter_skew(), 0.0);
        // renders without panicking
        let _ = s.summary();
        let _ = s.to_json().render();
    }
}
