//! The PULSE accelerator (paper §4.2): disaggregated logic + memory
//! pipelines, per-iterator workspaces, and the multiplexing scheduler.
//!
//! Split into:
//! * functional execution (`visit`) — really runs the ISA against the
//!   node's DRAM through the TCAM range table, producing the traversal
//!   result plus a per-iteration timing trace;
//! * timing (`des::AccelSim`) — replays traces against the m logic /
//!   n memory pipeline resources (or the coupled multi-core layout for
//!   the Table 4 ablation) on the virtual clock;
//! * `area` — LUT/BRAM model (Table 4 calibration).
//!
//! The logic pipeline has two interchangeable engines: the native Rust
//! interpreter (`interp::logic_pass`) and the AOT XLA artifact
//! (`runtime::LogicStepExe`, used via `XlaBatchEngine`) — bit-identical
//! by test.

pub mod area;
pub mod des;
pub mod xla_engine;

pub use area::AreaModel;
pub use des::{AccelSim, PipeStats};
pub use xla_engine::XlaBatchEngine;

use crate::interp::{logic_pass, Workspace};
use crate::isa::{Status, DATA_WORDS};
use crate::mem::translate::TranslateError;
use crate::mem::{NodeId, RangeTable, Region};
use crate::net::TraversalMsg;

/// Pipeline configuration of one accelerator.
#[derive(Debug, Clone, Copy)]
pub struct AccelConfig {
    /// Logic pipelines (m).
    pub m_logic: usize,
    /// Memory pipelines (n).
    pub n_mem: usize,
    /// Coupled (multi-core) mode for the Table 4 ablation: logic+memory
    /// pairs fused into cores; requires m_logic == n_mem.
    pub coupled: bool,
}

impl AccelConfig {
    /// Paper default: η = 0.75 ⇒ 3 logic + 4 memory pipelines (§4.2
    /// Implementation).
    pub fn paper_default() -> Self {
        Self { m_logic: 3, n_mem: 4, coupled: false }
    }

    pub fn eta(&self) -> f64 {
        self.m_logic as f64 / self.n_mem as f64
    }

    /// Workspace count: m + n suffices for any schedule (paper §4.2).
    pub fn workspaces(&self) -> usize {
        self.m_logic + self.n_mem
    }
}

/// Per-iteration timing trace entry, consumed by the DES.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IterTrace {
    /// Words fetched by the aggregated LOAD.
    pub words: u8,
    /// Dynamic instructions executed by the logic pipeline.
    pub instrs: u32,
    /// Whether the data window was written back.
    pub dirty: bool,
}

/// How a visit to this memory node ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VisitEnd {
    /// Traversal finished (Return) or faulted (Trap).
    Done(Status),
    /// `cur_ptr` is not resident here — bounce to the switch (paper §5).
    NotLocal,
    /// Iteration budget exhausted — yield to the CPU node (paper §3).
    Yield,
}

#[derive(Debug, Clone)]
pub struct VisitOutcome {
    pub end: VisitEnd,
    /// Iterations executed during this visit.
    pub iters: u32,
    pub trace: Vec<IterTrace>,
}

/// One memory node's accelerator: DRAM + TCAM + functional engine.
#[derive(Debug)]
pub struct Accelerator {
    pub node: NodeId,
    pub region: Region,
    pub table: RangeTable,
    pub cfg: AccelConfig,
    /// Reused workspace to avoid per-visit allocation (hot path).
    ws: Workspace,
    /// Counters.
    pub iterations: u64,
    pub traps: u64,
    pub bounces: u64,
}

impl Accelerator {
    pub fn new(
        node: NodeId,
        region: Region,
        table: RangeTable,
        cfg: AccelConfig,
    ) -> Self {
        Self {
            node,
            region,
            table,
            cfg,
            ws: Workspace::new(),
            iterations: 0,
            traps: 0,
            bounces: 0,
        }
    }

    /// Execute iterations of `msg`'s traversal while the pointer stays
    /// local and the budget lasts. Updates `msg` in place (cur_ptr, sp,
    /// iters_done) so it can be bounced/forwarded verbatim — request and
    /// response share the format (paper §5).
    pub fn visit(&mut self, msg: &mut TraversalMsg) -> VisitOutcome {
        // Arc bump, not a deep copy: detaches the program from the
        // &mut borrow of `msg` while sharing the same instructions.
        let program = std::sync::Arc::clone(&msg.program);
        let words = program.load_words as usize;
        let mut trace = Vec::with_capacity(8);
        let mut iters = 0u32;

        // Restore migrated state: scratchpad + cur_ptr only (registers
        // are per-iteration scratch — the cross-node contract, §5).
        self.ws.sp.copy_from_slice(&msg.sp);

        loop {
            if msg.iters_done >= msg.max_iters {
                msg.sp.copy_from_slice(&self.ws.sp);
                return VisitOutcome { end: VisitEnd::Yield, iters, trace };
            }
            // Memory pipeline: translate + aggregated load (§4.2).
            let local = match self.table.translate(
                msg.cur_ptr,
                (words * 8) as u64,
                false,
            ) {
                Ok(off) => off,
                Err(TranslateError::NotLocal) => {
                    msg.sp.copy_from_slice(&self.ws.sp);
                    msg.node_crossings += 1;
                    self.bounces += 1;
                    return VisitOutcome {
                        end: VisitEnd::NotLocal,
                        iters,
                        trace,
                    };
                }
                Err(TranslateError::Protection) => {
                    msg.sp.copy_from_slice(&self.ws.sp);
                    self.traps += 1;
                    return VisitOutcome {
                        end: VisitEnd::Done(Status::Trap),
                        iters,
                        trace,
                    };
                }
            };
            self.ws.data[..words].iter_mut().for_each(|w| *w = 0);
            self.region.read_words(local, &mut self.ws.data[..words]);
            if words < DATA_WORDS {
                self.ws.data[words..].iter_mut().for_each(|w| *w = 0);
            }

            // Logic pipeline: one pass. Registers reset each iteration;
            // r0 = cur_ptr.
            self.ws.regs = [0; crate::isa::NREG];
            self.ws.set_cur_ptr(msg.cur_ptr);
            let pass = logic_pass(&program, &mut self.ws);
            iters += 1;
            msg.iters_done += 1;
            self.iterations += 1;
            trace.push(IterTrace {
                words: program.load_words,
                instrs: pass.steps,
                dirty: program.writes_data,
            });

            // Write-back for mutating traversals.
            if program.writes_data {
                if let Ok(off) = self.table.translate(
                    msg.cur_ptr,
                    (words * 8) as u64,
                    true,
                ) {
                    self.region.write_words(off, &self.ws.data[..words]);
                } else {
                    msg.sp.copy_from_slice(&self.ws.sp);
                    self.traps += 1;
                    return VisitOutcome {
                        end: VisitEnd::Done(Status::Trap),
                        iters,
                        trace,
                    };
                }
            }

            match pass.status {
                Status::NextIter => {
                    msg.cur_ptr = self.ws.cur_ptr();
                    continue;
                }
                Status::Return => {
                    msg.sp.copy_from_slice(&self.ws.sp);
                    return VisitOutcome {
                        end: VisitEnd::Done(Status::Return),
                        iters,
                        trace,
                    };
                }
                Status::Trap | Status::Running => {
                    msg.sp.copy_from_slice(&self.ws.sp);
                    self.traps += 1;
                    return VisitOutcome {
                        end: VisitEnd::Done(Status::Trap),
                        iters,
                        trace,
                    };
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::SP_WORDS;
    use crate::mem::translate::Perms;
    use crate::net::RequestId;

    /// Build a node with a linked list laid out at 0x1000.
    fn node_with_list(kvs: &[(i64, i64)]) -> (Accelerator, u64) {
        let mut region = Region::new(1 << 20);
        let mut table = RangeTable::new(64);
        table.insert(0x1000, 0x10000, 0, Perms::RW).unwrap();
        let base = 0x1000u64;
        for (i, &(k, v)) in kvs.iter().enumerate() {
            let addr = base + (i as u64) * 32;
            let next = if i + 1 < kvs.len() {
                base + (i as u64 + 1) * 32
            } else {
                0
            };
            // local offset == addr - 0x1000
            region.write_words(addr - 0x1000, &[k, v, next as i64]);
        }
        let accel = Accelerator::new(
            0,
            region,
            table,
            AccelConfig::paper_default(),
        );
        (accel, base)
    }

    fn find_msg(start: u64, key: i64) -> TraversalMsg {
        let mut sp = [0i64; SP_WORDS];
        sp[0] = key;
        TraversalMsg::request(
            RequestId { cpu_node: 0, seq: 1 },
            crate::testgen::list_find_program(),
            start,
            sp,
            64,
        )
    }

    #[test]
    fn local_traversal_finds_key() {
        let (mut accel, start) = node_with_list(&[(1, 10), (2, 20), (3, 30)]);
        let mut msg = find_msg(start, 3);
        let out = accel.visit(&mut msg);
        assert_eq!(out.end, VisitEnd::Done(Status::Return));
        assert_eq!(out.iters, 3);
        assert_eq!(msg.sp[1], 30);
        assert_eq!(out.trace.len(), 3);
        assert!(out.trace.iter().all(|t| t.words == 3 && !t.dirty));
    }

    /// Zero-copy execute invariant: the accelerator runs the very
    /// program Arc the request carried — a visit never swaps in a
    /// deep-copied program, even across yield/bounce boundaries.
    #[test]
    fn visit_executes_the_shared_program_arc() {
        use std::sync::Arc;
        let (mut accel, start) = node_with_list(&[(1, 10), (2, 20)]);
        let p = Arc::new(crate::testgen::list_find_program());
        let mut sp = [0i64; SP_WORDS];
        sp[0] = 2;
        let mut msg = TraversalMsg::request(
            RequestId { cpu_node: 0, seq: 9 },
            Arc::clone(&p),
            start,
            sp,
            1, // force a yield mid-walk first
        );
        let out = accel.visit(&mut msg);
        assert_eq!(out.end, VisitEnd::Yield);
        assert!(Arc::ptr_eq(&msg.program, &p));
        msg.max_iters = 64;
        let out = accel.visit(&mut msg);
        assert_eq!(out.end, VisitEnd::Done(Status::Return));
        assert!(Arc::ptr_eq(&msg.program, &p));
    }

    #[test]
    fn miss_returns_not_found() {
        let (mut accel, start) = node_with_list(&[(1, 10), (2, 20)]);
        let mut msg = find_msg(start, 9);
        let out = accel.visit(&mut msg);
        assert_eq!(out.end, VisitEnd::Done(Status::Return));
        assert_eq!(msg.sp[2], i64::MAX);
    }

    #[test]
    fn non_local_pointer_bounces_with_state() {
        let (mut accel, start) = node_with_list(&[(1, 10)]);
        // point the tail at a remote address
        accel.region.write_words(16, &[0x0900_0000i64]);
        let mut msg = find_msg(start, 9);
        let out = accel.visit(&mut msg);
        assert_eq!(out.end, VisitEnd::NotLocal);
        assert_eq!(msg.cur_ptr, 0x0900_0000);
        assert_eq!(msg.iters_done, 1);
        assert_eq!(msg.node_crossings, 1);
        assert_eq!(accel.bounces, 1);
    }

    #[test]
    fn iteration_budget_yields() {
        let kvs: Vec<_> = (0..10).map(|i| (i as i64, i as i64)).collect();
        let (mut accel, start) = node_with_list(&kvs);
        let mut msg = find_msg(start, 99);
        msg.max_iters = 4;
        let out = accel.visit(&mut msg);
        assert_eq!(out.end, VisitEnd::Yield);
        assert_eq!(msg.iters_done, 4);
        // continuation: budget refreshed by the CPU node
        msg.max_iters = 64;
        let out = accel.visit(&mut msg);
        assert_eq!(out.end, VisitEnd::Done(Status::Return));
        assert_eq!(msg.sp[2], i64::MAX); // not found after full walk
        assert_eq!(msg.iters_done, 10);
    }

    #[test]
    fn trap_on_protection_fault() {
        let (mut accel, start) = node_with_list(&[(1, 10)]);
        // a read-only range the program will try to walk into
        accel.table.insert(0x100000, 0x1000, 0x20000, Perms::RO).unwrap();
        // write-back program (stores into the window)
        let mut a = crate::isa::Asm::new();
        a.movi(1, 7);
        a.std_(1, 0);
        a.ret();
        let p = a.finish(1).unwrap();
        let mut msg = TraversalMsg::request(
            RequestId { cpu_node: 0, seq: 2 },
            p,
            0x100000,
            [0i64; SP_WORDS],
            8,
        );
        let out = accel.visit(&mut msg);
        assert_eq!(out.end, VisitEnd::Done(Status::Trap));
        assert_eq!(accel.traps, 1);
        let _ = start;
    }

    #[test]
    fn stateful_sum_survives_yield_boundary() {
        // list_sum accumulates in sp[3] — splitting the traversal across
        // budget boundaries must not change the result.
        let kvs: Vec<_> = (1..=8).map(|i| (i as i64, 10 * i as i64)).collect();
        let (mut accel, start) = node_with_list(&kvs);
        let p = {
            let mut a = crate::isa::Asm::new();
            let done = a.label();
            a.spl(1, 3);
            a.ldd(2, 1);
            a.add(1, 1, 2);
            a.sps(1, 3);
            a.ldd(3, 2);
            a.movi(4, 0);
            a.jeq(3, 4, done);
            a.mov(0, 3);
            a.next();
            a.bind(done);
            a.ret();
            a.finish(3).unwrap()
        };
        let mut msg = TraversalMsg::request(
            RequestId { cpu_node: 0, seq: 3 },
            p,
            start,
            [0i64; SP_WORDS],
            3,
        );
        loop {
            let out = accel.visit(&mut msg);
            match out.end {
                VisitEnd::Yield => msg.max_iters += 3,
                VisitEnd::Done(Status::Return) => break,
                e => panic!("unexpected {e:?}"),
            }
        }
        assert_eq!(msg.sp[3], (1..=8).map(|i| 10 * i).sum::<i64>());
    }
}
