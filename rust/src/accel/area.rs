//! FPGA area model (Table 4): LUT% / BRAM% for coupled and disaggregated
//! pipeline configurations on the Alveo U250.
//!
//! We cannot synthesize RTL in this environment, so the 20 configurations
//! the paper measured are reproduced as calibrated data (exact Table 4
//! values), and other configurations (e.g. the η sweep in Fig. 11 that
//! reaches 16 memory pipelines) use a least-squares linear model fitted
//! to those measurements: `area ≈ base + a·m_logic + b·n_mem` (+ coupled
//! core packing discount). The fit is documented in DESIGN.md.

use super::AccelConfig;

/// (m, n) -> (LUT %, BRAM %) exactly as measured in Table 4.
const COUPLED: &[(usize, f64, f64)] = &[
    (1, 7.37, 7.29),
    (2, 10.23, 9.37),
    (3, 14.33, 15.92),
    (4, 18.55, 17.09),
];

const DISAGG: &[(usize, usize, f64, f64)] = &[
    (1, 1, 5.88, 8.17),
    (1, 2, 7.44, 9.14),
    (1, 3, 8.32, 11.19),
    (1, 4, 9.19, 12.92),
    (2, 1, 8.87, 10.19),
    (2, 2, 10.69, 11.19),
    (2, 3, 13.11, 13.38),
    (2, 4, 15.07, 15.61),
    (3, 1, 14.08, 11.93),
    (3, 2, 15.79, 13.78),
    (3, 3, 18.61, 15.06),
    (3, 4, 19.20, 17.47),
    (4, 1, 18.67, 14.17),
    (4, 2, 20.37, 16.02),
    (4, 3, 22.08, 17.86),
    (4, 4, 23.21, 19.92),
];

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Area {
    pub lut_pct: f64,
    pub bram_pct: f64,
}

#[derive(Debug, Clone, Copy)]
pub struct AreaModel {
    /// Linear fit coefficients for disaggregated configs:
    /// lut = l0 + l_m * m + l_n * n (same shape for bram).
    l0: f64,
    l_m: f64,
    l_n: f64,
    b0: f64,
    b_m: f64,
    b_n: f64,
}

impl Default for AreaModel {
    fn default() -> Self {
        Self::fit()
    }
}

impl AreaModel {
    /// Least-squares fit over the 16 disaggregated measurements.
    pub fn fit() -> Self {
        // Solve the 3-parameter LS by normal equations.
        let rows: Vec<(f64, f64, f64, f64)> = DISAGG
            .iter()
            .map(|&(m, n, lut, bram)| (m as f64, n as f64, lut, bram))
            .collect();
        let solve = |target: &dyn Fn(&(f64, f64, f64, f64)) -> f64| {
            // design matrix columns: 1, m, n
            let mut ata = [[0.0f64; 3]; 3];
            let mut atb = [0.0f64; 3];
            for r in &rows {
                let x = [1.0, r.0, r.1];
                let y = target(r);
                for i in 0..3 {
                    for j in 0..3 {
                        ata[i][j] += x[i] * x[j];
                    }
                    atb[i] += x[i] * y;
                }
            }
            // Gaussian elimination (3x3).
            let mut a = ata;
            let mut b = atb;
            for col in 0..3 {
                let piv = (col..3)
                    .max_by(|&i, &j| {
                        a[i][col].abs().total_cmp(&a[j][col].abs())
                    })
                    .unwrap();
                a.swap(col, piv);
                b.swap(col, piv);
                for row in col + 1..3 {
                    let f = a[row][col] / a[col][col];
                    for k in col..3 {
                        a[row][k] -= f * a[col][k];
                    }
                    b[row] -= f * b[col];
                }
            }
            let mut x = [0.0f64; 3];
            for row in (0..3).rev() {
                let mut s = b[row];
                for k in row + 1..3 {
                    s -= a[row][k] * x[k];
                }
                x[row] = s / a[row][row];
            }
            x
        };
        let l = solve(&|r: &(f64, f64, f64, f64)| r.2);
        let b = solve(&|r: &(f64, f64, f64, f64)| r.3);
        Self { l0: l[0], l_m: l[1], l_n: l[2], b0: b[0], b_m: b[1], b_n: b[2] }
    }

    /// Area of a configuration: exact Table 4 value when measured,
    /// linear-model extrapolation otherwise.
    pub fn area(&self, cfg: &AccelConfig) -> Area {
        if cfg.coupled {
            debug_assert_eq!(cfg.m_logic, cfg.n_mem);
            if let Some(&(_, lut, bram)) =
                COUPLED.iter().find(|&&(k, _, _)| k == cfg.m_logic)
            {
                return Area { lut_pct: lut, bram_pct: bram };
            }
            // coupled extrapolation: per-core slope from the table
            let k = cfg.m_logic as f64;
            return Area {
                lut_pct: 3.43 + 3.76 * k,
                bram_pct: 4.41 + 3.43 * k,
            };
        }
        if let Some(&(_, _, lut, bram)) = DISAGG
            .iter()
            .find(|&&(m, n, _, _)| m == cfg.m_logic && n == cfg.n_mem)
        {
            return Area { lut_pct: lut, bram_pct: bram };
        }
        Area {
            lut_pct: self.l0
                + self.l_m * cfg.m_logic as f64
                + self.l_n * cfg.n_mem as f64,
            bram_pct: self.b0
                + self.b_m * cfg.m_logic as f64
                + self.b_n * cfg.n_mem as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(m: usize, n: usize, coupled: bool) -> AccelConfig {
        AccelConfig { m_logic: m, n_mem: n, coupled }
    }

    #[test]
    fn measured_configs_exact() {
        let model = AreaModel::fit();
        let a = model.area(&cfg(1, 4, false));
        assert_eq!(a.lut_pct, 9.19);
        assert_eq!(a.bram_pct, 12.92);
        let a = model.area(&cfg(4, 4, true));
        assert_eq!(a.lut_pct, 18.55);
    }

    #[test]
    fn paper_headline_area_saving() {
        // PULSE 1L+4M vs coupled 4x4: 38% less LUT area (paper §6.2).
        let model = AreaModel::fit();
        let pulse = model.area(&cfg(1, 4, false)).lut_pct;
        let coupled = model.area(&cfg(4, 4, true)).lut_pct;
        let saving = 1.0 - pulse / coupled;
        assert!(
            (saving - 0.50).abs() < 0.15,
            "saving {saving}" // 1 - 9.19/18.55 ≈ 0.50; paper quotes 38%
                              // against total design area incl. shared IPs
        );
    }

    #[test]
    fn extrapolation_is_monotone() {
        let model = AreaModel::fit();
        let a8 = model.area(&cfg(1, 8, false));
        let a16 = model.area(&cfg(1, 16, false));
        let a4 = model.area(&cfg(1, 4, false));
        assert!(a8.lut_pct > a4.lut_pct);
        assert!(a16.lut_pct > a8.lut_pct);
        assert!(a16.bram_pct > a8.bram_pct);
    }

    #[test]
    fn fit_residuals_small() {
        let model = AreaModel::fit();
        // the fitted plane should track the measured grid within ~1.5%.
        let pred = Area {
            lut_pct: model.l0 + model.l_m * 2.0 + model.l_n * 3.0,
            bram_pct: model.b0 + model.b_m * 2.0 + model.b_n * 3.0,
        };
        assert!((pred.lut_pct - 13.11).abs() < 1.5, "{}", pred.lut_pct);
        assert!((pred.bram_pct - 13.38).abs() < 1.5, "{}", pred.bram_pct);
    }
}
