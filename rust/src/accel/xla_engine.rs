//! Batched logic-pipeline engine with two interchangeable backends.
//!
//! Realizes the accelerator's logic pipeline either natively (the Rust
//! interpreter, always available) or with the AOT XLA artifact
//! (L1 Pallas kernel lowered through L2 jax, compiled once via PJRT):
//! concurrent in-flight iterators running the *same program* are packed
//! into lanes of one `logic_batch_step` call, mirroring how the FPGA
//! logic pipeline multiplexes workspaces. Semantics are bit-identical
//! between the two (enforced by integration tests).
//!
//! The XLA backend is gated behind the `xla` cargo feature (the
//! default build is std-only); without it only `native()` exists and
//! `step` never fails.

use crate::interp::{logic_pass, Workspace};
use crate::isa::{Program, Status};
#[cfg(feature = "xla")]
use crate::runtime::LogicStepExe;

/// Engine failure (only reachable through the XLA backend).
#[derive(Debug)]
pub struct EngineError(pub String);

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "logic engine error: {}", self.0)
    }
}

impl std::error::Error for EngineError {}

/// Batch executor over same-program workspaces.
pub struct XlaBatchEngine<'a> {
    #[cfg(feature = "xla")]
    exe: Option<&'a LogicStepExe>,
    #[cfg(not(feature = "xla"))]
    _marker: std::marker::PhantomData<&'a ()>,
}

impl<'a> XlaBatchEngine<'a> {
    /// Native-interpreter engine (the latency-critical default).
    pub fn native() -> Self {
        Self {
            #[cfg(feature = "xla")]
            exe: None,
            #[cfg(not(feature = "xla"))]
            _marker: std::marker::PhantomData,
        }
    }

    /// XLA-artifact engine (exercises/measures the three-layer stack).
    #[cfg(feature = "xla")]
    pub fn xla(exe: &'a LogicStepExe) -> Self {
        Self { exe: Some(exe) }
    }

    #[cfg(feature = "xla")]
    pub fn is_xla(&self) -> bool {
        self.exe.is_some()
    }

    #[cfg(not(feature = "xla"))]
    pub fn is_xla(&self) -> bool {
        false
    }

    /// Run one logic pass over every workspace (all running `program`).
    /// With the XLA engine the batch is chunked to the artifact's lane
    /// count; with the native engine lanes execute sequentially.
    pub fn step(
        &self,
        program: &Program,
        ws: &mut [Workspace],
    ) -> Result<Vec<Status>, EngineError> {
        #[cfg(feature = "xla")]
        if let Some(exe) = self.exe {
            let mut out = Vec::with_capacity(ws.len());
            for chunk in ws.chunks_mut(exe.batch) {
                out.extend(
                    exe.run(program, chunk)
                        .map_err(|e| EngineError(e.to_string()))?,
                );
            }
            return Ok(out);
        }
        Ok(ws
            .iter_mut()
            .map(|w| logic_pass(program, w).status)
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Asm;

    #[test]
    fn native_engine_steps_batch() {
        let mut a = Asm::new();
        a.spl(1, 0);
        a.addi(1, 1, 5);
        a.sps(1, 1);
        a.ret();
        let p = a.finish(1).unwrap();
        let mut ws: Vec<Workspace> = (0..7)
            .map(|i| {
                let mut w = Workspace::new();
                w.sp[0] = i;
                w
            })
            .collect();
        let eng = XlaBatchEngine::native();
        assert!(!eng.is_xla());
        let st = eng.step(&p, &mut ws).unwrap();
        assert!(st.iter().all(|&s| s == Status::Return));
        for (i, w) in ws.iter().enumerate() {
            assert_eq!(w.sp[1], i as i64 + 5);
        }
    }
}
