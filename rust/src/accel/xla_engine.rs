//! XLA-backed batched logic-pipeline engine.
//!
//! Realizes the accelerator's logic pipeline with the AOT artifact
//! (L1 Pallas kernel lowered through L2 jax, compiled once via PJRT):
//! concurrent in-flight iterators running the *same program* are packed
//! into lanes of one `logic_batch_step` call, mirroring how the FPGA
//! logic pipeline multiplexes workspaces. Semantics are bit-identical to
//! the native interpreter (enforced by integration tests); use `Native`
//! for latency-critical paths and `Xla` to exercise/measure the
//! three-layer stack.

use anyhow::Result;

use crate::interp::{logic_pass, Workspace};
use crate::isa::{Program, Status};
use crate::runtime::LogicStepExe;

/// Which engine executes logic passes.
pub enum Engine<'a> {
    Native,
    Xla(&'a LogicStepExe),
}

/// Batch executor over same-program workspaces.
pub struct XlaBatchEngine<'a> {
    engine: Engine<'a>,
}

impl<'a> XlaBatchEngine<'a> {
    pub fn native() -> Self {
        Self { engine: Engine::Native }
    }

    pub fn xla(exe: &'a LogicStepExe) -> Self {
        Self { engine: Engine::Xla(exe) }
    }

    pub fn is_xla(&self) -> bool {
        matches!(self.engine, Engine::Xla(_))
    }

    /// Run one logic pass over every workspace (all running `program`).
    /// With the XLA engine the batch is chunked to the artifact's lane
    /// count; with the native engine lanes execute sequentially.
    pub fn step(
        &self,
        program: &Program,
        ws: &mut [Workspace],
    ) -> Result<Vec<Status>> {
        match &self.engine {
            Engine::Native => Ok(ws
                .iter_mut()
                .map(|w| logic_pass(program, w).status)
                .collect()),
            Engine::Xla(exe) => {
                let mut out = Vec::with_capacity(ws.len());
                for chunk in ws.chunks_mut(exe.batch) {
                    out.extend(exe.run(program, chunk)?);
                }
                Ok(out)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Asm;

    #[test]
    fn native_engine_steps_batch() {
        let mut a = Asm::new();
        a.spl(1, 0);
        a.addi(1, 1, 5);
        a.sps(1, 1);
        a.ret();
        let p = a.finish(1).unwrap();
        let mut ws: Vec<Workspace> = (0..7)
            .map(|i| {
                let mut w = Workspace::new();
                w.sp[0] = i;
                w
            })
            .collect();
        let eng = XlaBatchEngine::native();
        let st = eng.step(&p, &mut ws).unwrap();
        assert!(st.iter().all(|&s| s == Status::Return));
        for (i, w) in ws.iter().enumerate() {
            assert_eq!(w.sp[1], i as i64 + 5);
        }
    }
}
