//! Discrete-event timing model of the accelerator's pipeline resources.
//!
//! Replays functional traces (`IterTrace`) against m logic + n memory
//! pipelines with the paper's multiplexing scheduler (Fig. 4 / Algorithm
//! 1): each iteration is a memory phase (any free memory pipeline)
//! followed by a dependent logic phase (any free logic pipeline);
//! different iterators overlap freely. The workspace count (m + n)
//! bounds admission (§4.2). Coupled (multi-core, Table 4) mode fuses
//! each logic+memory pair into a core that a request occupies for the
//! whole iteration — the under-utilization Fig. 4 (top) illustrates.
//!
//! This is a true event-driven simulation (not greedy reservation), so
//! later arrivals backfill pipeline idle gaps exactly as the hardware
//! scheduler does.

use super::{AccelConfig, IterTrace};
use crate::sim::{EventQueue, LatencyModel, Ns};
use std::collections::VecDeque;

#[derive(Debug, Default, Clone, Copy)]
pub struct PipeStats {
    pub mem_busy_ns: u64,
    pub logic_busy_ns: u64,
    pub visits: u64,
    pub iterations: u64,
    /// Completion time of the latest visit (makespan).
    pub makespan_ns: Ns,
}

impl PipeStats {
    /// Utilization of the memory pipelines over the makespan.
    pub fn mem_util(&self, n_mem: usize) -> f64 {
        if self.makespan_ns == 0 {
            return 0.0;
        }
        self.mem_busy_ns as f64 / (self.makespan_ns as f64 * n_mem as f64)
    }

    pub fn logic_util(&self, m_logic: usize) -> f64 {
        if self.makespan_ns == 0 {
            return 0.0;
        }
        self.logic_busy_ns as f64
            / (self.makespan_ns as f64 * m_logic as f64)
    }
}

/// One visit to schedule: arrival time + functional trace.
#[derive(Debug, Clone)]
pub struct VisitSpec {
    pub arrive: Ns,
    pub trace: Vec<IterTrace>,
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    Arrive(usize),
    MemDone(usize),
    LogicDone(usize),
    CoreDone(usize),
}

struct VisitState {
    trace: Vec<IterTrace>,
    iter: usize,
    done_at: Option<Ns>,
}

/// Counting resource with FIFO waiters.
struct ResPool {
    free: usize,
    wait: VecDeque<usize>,
}

impl ResPool {
    fn new(k: usize) -> Self {
        Self { free: k, wait: VecDeque::new() }
    }
}

#[derive(Debug)]
pub struct AccelSim {
    cfg: AccelConfig,
    lat: LatencyModel,
    pub stats: PipeStats,
}

impl AccelSim {
    pub fn new(cfg: AccelConfig, lat: LatencyModel) -> Self {
        assert!(
            !cfg.coupled || cfg.m_logic == cfg.n_mem,
            "coupled mode requires m == n"
        );
        Self { cfg, lat, stats: PipeStats::default() }
    }

    pub fn cfg(&self) -> AccelConfig {
        self.cfg
    }

    fn mem_dur(&self, it: &IterTrace) -> Ns {
        self.lat.mem_pipe_ns(it.words as usize, it.dirty)
    }

    fn logic_dur(&self, it: &IterTrace) -> Ns {
        self.lat.logic_ns(it.instrs).max(1)
    }

    /// Simulate all visits; returns per-visit departure times (response
    /// leaving the accelerator's network stack), parallel to `visits`.
    pub fn run(&mut self, visits: &[VisitSpec]) -> Vec<Ns> {
        let mut q: EventQueue<Ev> = EventQueue::new();
        let mut vs: Vec<VisitState> = visits
            .iter()
            .map(|v| VisitState {
                trace: v.trace.clone(),
                iter: 0,
                done_at: None,
            })
            .collect();

        let ns_in = self.lat.accel_net_stack_ns as Ns;
        let sched = self.lat.accel_sched_ns as Ns;

        let mut workspaces = ResPool::new(self.cfg.workspaces());
        let mut mem = ResPool::new(self.cfg.n_mem);
        let mut logic = ResPool::new(self.cfg.m_logic);
        let mut cores = ResPool::new(self.cfg.n_mem); // coupled mode

        for (i, v) in visits.iter().enumerate() {
            q.push(v.arrive + ns_in, Ev::Arrive(i));
        }

        macro_rules! start_iter {
            ($now:expr, $vid:expr, $q:expr) => {{
                let vid = $vid;
                if self.cfg.coupled {
                    if cores.free > 0 {
                        cores.free -= 1;
                        let it = vs[vid].trace[vs[vid].iter];
                        let dur = self.mem_dur(&it) + self.logic_dur(&it);
                        self.stats.mem_busy_ns += self.mem_dur(&it);
                        self.stats.logic_busy_ns += self.logic_dur(&it);
                        $q.push($now + dur, Ev::CoreDone(vid));
                    } else {
                        cores.wait.push_back(vid);
                    }
                } else if mem.free > 0 {
                    mem.free -= 1;
                    let dur = self.mem_dur(&vs[vid].trace[vs[vid].iter]);
                    self.stats.mem_busy_ns += dur;
                    $q.push($now + dur, Ev::MemDone(vid));
                } else {
                    mem.wait.push_back(vid);
                }
            }};
        }

        macro_rules! finish_visit {
            ($now:expr, $vid:expr, $q:expr) => {{
                let vid = $vid;
                vs[vid].done_at = Some($now + ns_in);
                self.stats.visits += 1;
                self.stats.makespan_ns =
                    self.stats.makespan_ns.max($now + ns_in);
                // release the workspace; admit a waiter
                if let Some(w) = workspaces.wait.pop_front() {
                    start_iter!($now + sched, w, $q);
                } else {
                    workspaces.free += 1;
                }
            }};
        }

        while let Some((now, ev)) = q.pop() {
            match ev {
                Ev::Arrive(vid) => {
                    if vs[vid].trace.is_empty() {
                        // zero-iteration visit (e.g. immediate bounce)
                        vs[vid].done_at = Some(now + ns_in);
                        self.stats.visits += 1;
                        self.stats.makespan_ns =
                            self.stats.makespan_ns.max(now + ns_in);
                        continue;
                    }
                    if workspaces.free > 0 {
                        workspaces.free -= 1;
                        start_iter!(now + sched, vid, q);
                    } else {
                        workspaces.wait.push_back(vid);
                    }
                }
                Ev::MemDone(vid) => {
                    // free the memory pipeline; hand to next waiter
                    if let Some(w) = mem.wait.pop_front() {
                        let dur = self.mem_dur(&vs[w].trace[vs[w].iter]);
                        self.stats.mem_busy_ns += dur;
                        q.push(now + dur, Ev::MemDone(w));
                    } else {
                        mem.free += 1;
                    }
                    // this visit proceeds to its logic phase
                    if logic.free > 0 {
                        logic.free -= 1;
                        let dur =
                            self.logic_dur(&vs[vid].trace[vs[vid].iter]);
                        self.stats.logic_busy_ns += dur;
                        q.push(now + dur, Ev::LogicDone(vid));
                    } else {
                        logic.wait.push_back(vid);
                    }
                }
                Ev::LogicDone(vid) => {
                    if let Some(w) = logic.wait.pop_front() {
                        let dur = self.logic_dur(&vs[w].trace[vs[w].iter]);
                        self.stats.logic_busy_ns += dur;
                        q.push(now + dur, Ev::LogicDone(w));
                    } else {
                        logic.free += 1;
                    }
                    self.stats.iterations += 1;
                    vs[vid].iter += 1;
                    if vs[vid].iter < vs[vid].trace.len() {
                        start_iter!(now + sched, vid, q);
                    } else {
                        finish_visit!(now, vid, q);
                    }
                }
                Ev::CoreDone(vid) => {
                    if let Some(w) = cores.wait.pop_front() {
                        let it = vs[w].trace[vs[w].iter];
                        let dur = self.mem_dur(&it) + self.logic_dur(&it);
                        self.stats.mem_busy_ns += self.mem_dur(&it);
                        self.stats.logic_busy_ns += self.logic_dur(&it);
                        q.push(now + dur, Ev::CoreDone(w));
                    } else {
                        cores.free += 1;
                    }
                    self.stats.iterations += 1;
                    vs[vid].iter += 1;
                    if vs[vid].iter < vs[vid].trace.len() {
                        start_iter!(now + sched, vid, q);
                    } else {
                        finish_visit!(now, vid, q);
                    }
                }
            }
        }

        vs.into_iter().map(|v| v.done_at.expect("visit unfinished")).collect()
    }

    /// Convenience: single visit, returning its departure time.
    pub fn schedule_visit(&mut self, arrive: Ns, trace: &[IterTrace]) -> Ns {
        self.run(&[VisitSpec { arrive, trace: trace.to_vec() }])[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(iters: usize, words: u8, instrs: u32) -> Vec<IterTrace> {
        vec![IterTrace { words, instrs, dirty: false }; iters]
    }

    fn lat() -> LatencyModel {
        LatencyModel::default()
    }

    fn burst(n: usize, tr: &[IterTrace]) -> Vec<VisitSpec> {
        (0..n)
            .map(|_| VisitSpec { arrive: 0, trace: tr.to_vec() })
            .collect()
    }

    #[test]
    fn single_visit_latency_composition() {
        let mut sim = AccelSim::new(
            AccelConfig { m_logic: 1, n_mem: 1, coupled: false },
            lat(),
        );
        let t = sim.schedule_visit(0, &trace(1, 3, 10));
        let l = lat();
        let expect = (2.0 * l.accel_net_stack_ns + l.accel_sched_ns) as Ns
            + l.mem_pipe_ns(3, false)
            + l.logic_ns(10);
        assert_eq!(t, expect);
    }

    #[test]
    fn disaggregated_overlaps_memory_phases() {
        let tr = trace(4, 32, 4);
        let mut dis = AccelSim::new(
            AccelConfig { m_logic: 1, n_mem: 2, coupled: false },
            lat(),
        );
        let d = dis.run(&burst(4, &tr));
        let mut cpl = AccelSim::new(
            AccelConfig { m_logic: 1, n_mem: 1, coupled: true },
            lat(),
        );
        let c = cpl.run(&burst(4, &tr));
        assert!(
            d.iter().max() < c.iter().max(),
            "disagg {:?} coupled {:?}",
            d.iter().max(),
            c.iter().max()
        );
    }

    #[test]
    fn eta_matched_load_saturates_memory_pipelines() {
        // t_c = 0.5 t_d with m=1, n=2 (η = 0.5): steady stream keeps
        // memory pipelines nearly fully busy (Fig. 4 bottom).
        let l = lat();
        let words = 32usize;
        let mem_ns = l.mem_pipe_ns(words, false);
        let instrs = (mem_ns / 2 / l.accel_instr_ns as u64) as u32;
        let tr = trace(64, words as u8, instrs);
        let mut sim = AccelSim::new(
            AccelConfig { m_logic: 1, n_mem: 2, coupled: false },
            lat(),
        );
        sim.run(&burst(8, &tr));
        let mem_util = sim.stats.mem_util(2);
        assert!(mem_util > 0.8, "mem util {mem_util}");
        let logic_util = sim.stats.logic_util(1);
        assert!(logic_util > 0.7, "logic util {logic_util}");
    }

    #[test]
    fn more_memory_pipelines_increase_throughput() {
        let tr = trace(8, 32, 8);
        let make = |n_mem: usize| {
            let mut sim = AccelSim::new(
                AccelConfig { m_logic: 1, n_mem, coupled: false },
                lat(),
            );
            *sim.run(&burst(32, &tr)).iter().max().unwrap()
        };
        let t1 = make(1);
        let t2 = make(2);
        let t4 = make(4);
        assert!(t2 < t1);
        assert!(t4 < t2);
        let speedup = t1 as f64 / t4 as f64;
        assert!(speedup > 2.5, "speedup {speedup}");
    }

    #[test]
    fn workspace_bound_limits_concurrency() {
        // m+n = 2 workspaces; 6 long visits cannot all be in flight.
        let cfg = AccelConfig { m_logic: 1, n_mem: 1, coupled: false };
        let mut sim = AccelSim::new(cfg, lat());
        let tr = trace(16, 32, 8);
        let done = sim.run(&burst(6, &tr));
        let mut sorted = done.clone();
        sorted.sort_unstable();
        // strictly staged completion waves
        assert!(sorted[5] > sorted[1]);
        assert!(sorted[5] as f64 > 2.5 * sorted[0] as f64);
    }

    #[test]
    fn coupled_equals_disagg_for_single_request() {
        let tr = trace(5, 16, 12);
        let mut dis = AccelSim::new(
            AccelConfig { m_logic: 1, n_mem: 1, coupled: false },
            lat(),
        );
        let mut cpl = AccelSim::new(
            AccelConfig { m_logic: 1, n_mem: 1, coupled: true },
            lat(),
        );
        assert_eq!(
            dis.schedule_visit(0, &tr),
            cpl.schedule_visit(0, &tr)
        );
    }

    #[test]
    fn zero_iteration_visit_passes_through() {
        let mut sim = AccelSim::new(AccelConfig::paper_default(), lat());
        let t = sim.schedule_visit(100, &[]);
        let l = lat();
        assert_eq!(t, 100 + 2 * l.accel_net_stack_ns as Ns);
    }

    #[test]
    fn paper_table4_shape_disagg_matches_coupled_throughput_less_area() {
        // WebService-like load: t_c/t_d ≈ 0.06 (Table 3). Disaggregated
        // 1L+4M should be within a few % of coupled 4x4 throughput.
        let l = lat();
        let tr = trace(48, 8, 3); // hash chain walk
        let reqs = burst(64, &tr);
        let mut dis = AccelSim::new(
            AccelConfig { m_logic: 1, n_mem: 4, coupled: false },
            l.clone(),
        );
        let d = *dis.run(&reqs).iter().max().unwrap();
        let mut cpl = AccelSim::new(
            AccelConfig { m_logic: 4, n_mem: 4, coupled: true },
            l,
        );
        let c = *cpl.run(&reqs).iter().max().unwrap();
        let ratio = d as f64 / c as f64;
        assert!(
            ratio < 1.15,
            "1L+4M should track coupled 4x4: ratio {ratio}"
        );
    }
}
