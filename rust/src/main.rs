//! `pulse` — leader binary / CLI for the PULSE reproduction.
//!
//! Subcommands:
//!   serve    — closed-loop serving of an app workload on a simulated
//!              rack, printing latency/throughput (the Fig. 7 row for
//!              one configuration)
//!   inspect  — compile a named data-structure iterator and print its
//!              PULSE-ISA listing + cost-model verdict
//!   selftest — verify the AOT XLA artifacts against the native
//!              interpreter (three-layer contract)
//!
//! Examples:
//!   pulse serve --app webservice --nodes 4 --ops 2000 --conc 32
//!   pulse serve --app btrdb --window-s 4 --nodes 2
//!   pulse serve --app wiredtiger --backend live --nodes 4
//!   pulse serve --mix a --backend pulse        (YCSB-A read/write mix)
//!   pulse inspect --iter bplustree-update
//!   pulse selftest

use pulse::apps::{BtrDbApp, WebServiceApp, WiredTigerApp};
use pulse::bench_support::{
    build_scenario_ops, build_write_mix_ops, make_backend, ScenarioSpec,
    WriteMixSpec,
};
use pulse::rack::RackConfig;
use pulse::util::cli::Args;
use pulse::workloads::{YcsbSpec, YcsbWorkload};

const SEC: i64 = 1_000_000_000;

type CliResult = Result<(), Box<dyn std::error::Error>>;

fn main() -> CliResult {
    let args = Args::parse();
    match args.subcommand() {
        Some("serve") => serve(&args),
        Some("inspect") => inspect(&args),
        Some("selftest") => selftest(),
        _ => {
            eprintln!(
                "usage: pulse <serve|inspect|selftest> [--app webservice|\
                 wiredtiger|btrdb|skiplist|radixtrie|graph] [--backend \
                 pulse|pulse-acc|cache|rpc|rpc-arm|cache-rpc|live] \
                 [--mix a|b] [--nodes N] [--ops N] [--conc N] \
                 [--ycsb A|B|C|E] [--window-s S] [--uniform] \
                 [--granularity BYTES] [--loss P] [--no-in-network] \
                 [--hops N] [--iter NAME]"
            );
            std::process::exit(2);
        }
    }
}

fn cfg_from(args: &Args) -> RackConfig {
    let mut cfg = RackConfig {
        nodes: args.usize_or("nodes", 4),
        node_capacity: args.u64_or("node-capacity", 1 << 30),
        granularity: args.u64_or("granularity", 8 << 20),
        loss: args.f64_or("loss", 0.0),
        in_network_routing: !args.flag("no-in-network"),
        seed: args.u64_or("seed", 42),
        ..Default::default()
    };
    cfg.dispatch.cache_bytes = args.u64_or("cache-bytes", 0);
    cfg
}

fn serve(args: &Args) -> CliResult {
    let app_name = args.str_or("app", "webservice");
    let kind = args.str_or("backend", "pulse");
    let ops_n = args.u64_or("ops", 2_000);
    let conc = args.usize_or("conc", 32);
    let zipf = !args.flag("uniform");
    let seed = args.u64_or("seed", 42);
    // any compared system behind the unified trait: the rack DES
    // (pulse/pulse-acc), the model baselines, or the live
    // multi-threaded engine (one real worker thread per memory node)
    let mut backend = make_backend(&kind, cfg_from(args));

    // mixed read-write serving (`--mix a|b`): YCSB-A/B over the hash
    // index with offloaded put-on-existing-key updates — the write-path
    // workload, independent of `--app`
    if let Some(mix) = args.get("mix") {
        let spec = match mix {
            "a" | "A" => YcsbSpec::A,
            "b" | "B" => YcsbSpec::B,
            other => {
                return Err(
                    format!("--mix expects a|b, got {other:?}").into()
                )
            }
        };
        let wspec = WriteMixSpec {
            keys: args.u64_or("keys", 20_000),
            ops: ops_n,
            zipf,
            seed,
        };
        let ops = build_write_mix_ops(backend.rack_mut(), spec, &wspec);
        let report = backend.serve_batch(&ops, conc);
        print_report(
            &format!("{} write-mix", spec.name()),
            backend.as_mut(),
            conc,
            &report,
        );
        return Ok(());
    }

    let report = match app_name.as_str() {
        "webservice" => {
            let users = args.u64_or("keys", 5_000);
            let spec = match args.str_or("ycsb", "B").as_str() {
                "A" => YcsbSpec::A,
                "C" => YcsbSpec::C,
                _ => YcsbSpec::B,
            };
            let app =
                WebServiceApp::build(backend.rack_mut(), users, seed);
            let w = YcsbWorkload::new(spec, users, zipf, seed ^ 1);
            let mut ops = app.op_stream(w, ops_n);
            backend.serve(&mut |i| ops(i), conc)
        }
        "wiredtiger" => {
            let keys = args.u64_or("keys", 100_000);
            let app =
                WiredTigerApp::build(backend.rack_mut(), keys, seed);
            let w = YcsbWorkload::new(YcsbSpec::E, keys, zipf, seed ^ 1)
                .with_max_scan(args.usize_or("max-scan", 100));
            let mut ops = app.op_stream(w, ops_n);
            backend.serve(&mut |i| ops(i), conc)
        }
        "btrdb" => {
            let samples = args.usize_or("keys", 60_000);
            let app =
                BtrDbApp::build(backend.rack_mut(), samples, seed);
            let win = args.u64_or("window-s", 1) as i64 * SEC;
            let mut ops = app.op_stream(win, ops_n, seed ^ 1);
            backend.serve(&mut |i| ops(i), conc)
        }
        // scenario-expansion apps: skiplist (YCSB-E scans), radixtrie
        // (YCSB-C lookups), graph (bounded k-hop walks). The workload
        // builder is shared with benches/scenarios.rs, so the CLI
        // serves exactly the stream BENCH_scenarios.json reports.
        "skiplist" | "radixtrie" | "graph" => {
            let which = match app_name.as_str() {
                "skiplist" => "skiplist-e",
                "radixtrie" => "trie-lookup",
                _ => "graph-khop",
            };
            let spec = ScenarioSpec {
                keys: args.u64_or("keys", 20_000),
                ops: ops_n,
                zipf,
                max_scan: args.usize_or("max-scan", 60),
                // clamp instead of letting the generator's assert panic
                max_hops: args
                    .u64_or("hops", 8)
                    .clamp(1, pulse::ds::graph::MAX_HOPS as u64)
                    as u32,
                seed,
                ..Default::default()
            };
            let ops =
                build_scenario_ops(backend.rack_mut(), which, &spec);
            backend.serve(&mut |i| ops.get(i as usize).cloned(), conc)
        }
        other => return Err(format!("unknown app {other:?}").into()),
    };

    print_report(&app_name, backend.as_mut(), conc, &report);
    Ok(())
}

fn print_report(
    app_label: &str,
    backend: &mut dyn pulse::backend::TraversalBackend,
    conc: usize,
    report: &pulse::rack::ServeReport,
) {
    let (p50, p95, p99) = report.latency_percentiles();
    println!(
        "app={app_label} backend={} nodes={} ops={} conc={conc}",
        backend.name(),
        backend.rack_mut().cfg.nodes,
        report.completed
    );
    println!(
        "latency: p50={:.1}us p95={:.1}us p99={:.1}us mean={:.1}us",
        p50 as f64 / 1e3,
        p95 as f64 / 1e3,
        p99 as f64 / 1e3,
        report.latency.mean() / 1e3
    );
    println!(
        "throughput: {:.0} ops/s  (makespan {:.2} ms, {:.0} ms wall)",
        report.tput_ops_per_s,
        report.makespan_ns as f64 / 1e6,
        report.wall_ms
    );
    println!(
        "iters/op={:.1} cross-node-reqs={} retransmits={} traps={}",
        report.total_iters as f64 / report.completed.max(1) as f64,
        report.cross_node_requests,
        report.retransmits,
        report.trapped
    );
    // the DES routes through the rack's switch model; the live engine
    // and the trace-replay baselines keep their own routing counters,
    // so only print the switch line when it actually saw traffic
    let sw = backend.rack_mut().switch.stats;
    if sw.routed_requests > 0 {
        println!(
            "switch: routed={} reroutes={}",
            sw.routed_requests, sw.reroutes
        );
    }
}

fn inspect(args: &Args) -> CliResult {
    let name = args.str_or("iter", "list-find");
    let iter = match name.as_str() {
        "list-find" => pulse::ds::list::find_iter(),
        "list-sum" => pulse::ds::list::sum_iter(),
        "chain-find" => pulse::ds::hashmap::chain_find_iter(),
        "chain-update" => pulse::ds::hashmap::chain_update_iter(),
        "bst-lower-bound" => pulse::ds::bst::lower_bound_iter(),
        "btree-locate" => pulse::ds::btree::locate_iter(),
        "bplustree-get" => pulse::ds::bplustree::get_iter(),
        "bplustree-scan" => pulse::ds::bplustree::scan_iter(),
        "bplustree-sum" => pulse::ds::bplustree::sum_iter(),
        "bplustree-update" => pulse::ds::bplustree::update_iter(),
        "list-push-front" => pulse::ds::list::push_front_iter(),
        "skiplist-find" => pulse::ds::skiplist::find_iter(),
        "skiplist-locate" => pulse::ds::skiplist::locate_iter(),
        "skiplist-scan" => pulse::ds::skiplist::scan_iter(),
        "radixtrie-lookup" => pulse::ds::radixtrie::lookup_iter(),
        "graph-khop" => pulse::ds::graph::khop_iter(),
        other => {
            return Err(format!(
                "unknown iterator {other:?} (try list-find, \
                 list-push-front, chain-find, chain-update, \
                 bst-lower-bound, btree-locate, bplustree-get, \
                 bplustree-scan, bplustree-sum, bplustree-update, \
                 skiplist-find, skiplist-scan, radixtrie-lookup, \
                 graph-khop)"
            )
            .into())
        }
    };
    println!(
        "{name}: {} instructions, loads {} words/iteration{}",
        iter.program.len(),
        iter.program.load_words,
        if iter.program.writes_data { ", writes back" } else { "" }
    );
    println!(
        "t_c={:.0}ns t_d={:.0}ns ratio={:.2} -> {}",
        iter.t_c_ns,
        iter.t_d_ns,
        iter.ratio(),
        if iter.offloadable(0.75) {
            "OFFLOAD (t_c <= 0.75 t_d)"
        } else {
            "CPU fallback"
        }
    );
    for (pc, i) in iter.program.instrs.iter().enumerate() {
        println!("  {pc:2}: {i}");
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn selftest() -> CliResult {
    use pulse::interp::logic_pass;
    use pulse::runtime::PjrtRuntime;
    use pulse::util::prng::Rng;

    let rt = PjrtRuntime::new(PjrtRuntime::default_dir())?;
    let exe = rt.load_logic_step(32)?;
    let mut rng = Rng::new(0xDEC0DE);
    for case in 0..20 {
        let p = pulse::testgen::random_verified_program(&mut rng, 24);
        let mut xla: Vec<_> = (0..32)
            .map(|_| pulse::testgen::random_workspace(&mut rng))
            .collect();
        let mut native = xla.clone();
        let st = exe.run(&p, &mut xla)?;
        for (i, w) in native.iter_mut().enumerate() {
            let r = logic_pass(&p, w);
            if st[i] != r.status {
                return Err(
                    format!("case {case} lane {i}: status diverged").into()
                );
            }
        }
        if xla != native {
            return Err(format!("case {case}: workspace diverged").into());
        }
    }
    println!("selftest OK: XLA artifact = native interpreter (20 cases x 32 lanes)");
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn selftest() -> CliResult {
    println!(
        "selftest: the PJRT/XLA runtime path is disabled in this build; \
         rebuild with `--features xla` (requires the vendored xla-rs \
         crate and `make artifacts`) to verify the AOT artifacts against \
         the native interpreter."
    );
    Ok(())
}
