//! `pulse` — leader binary / CLI for the PULSE reproduction.
//!
//! Subcommands:
//!   serve    — closed-loop serving of an app workload on a simulated
//!              rack, printing latency/throughput (the Fig. 7 row for
//!              one configuration); with `--listen ADDR` it instead
//!              builds the workload's structures and serves them over
//!              TCP (the `srv` wire tier) until shutdown
//!   loadgen  — network load generator: build the same workload
//!              against a shadow rack and drive a listening server
//!              over real sockets (closed- or open-loop)
//!   inspect  — compile a named data-structure iterator and print its
//!              PULSE-ISA listing + cost-model verdict
//!   selftest — verify the AOT XLA artifacts against the native
//!              interpreter (three-layer contract)
//!
//! Examples:
//!   pulse serve --app webservice --nodes 4 --ops 2000 --conc 32
//!   pulse serve --app btrdb --window-s 4 --nodes 2
//!   pulse serve --app wiredtiger --backend live --nodes 4
//!   pulse serve --mix a --backend pulse        (YCSB-A read/write mix)
//!   pulse serve --listen 127.0.0.1:7311 --backend live --mix c
//!   pulse loadgen --addr 127.0.0.1:7311 --mix c --conns 8 --depth 16
//!   pulse inspect --iter bplustree-update
//!   pulse selftest
//!
//! serve --listen / loadgen contract: both sides must agree on the
//! rack shape (--nodes/--granularity/--seed) and the workload spec
//! (--mix or --app, --keys, --ops, --seed) — the client materializes
//! its op stream against an identically seeded shadow rack, which is
//! what makes its start pointers valid on the server.

use pulse::apps::{BtrDbApp, WebServiceApp, WiredTigerApp};
use pulse::bench_support::{
    build_scenario_ops, build_serving_ops, build_write_mix_ops,
    make_backend, ScenarioSpec, ServingSpec, WriteMixSpec,
};
use pulse::rack::{Rack, RackConfig};
use pulse::srv::{run_loadgen, LoadgenConfig, Server, SrvConfig};
use pulse::util::cli::Args;
use pulse::workloads::{YcsbSpec, YcsbWorkload};

const SEC: i64 = 1_000_000_000;

type CliResult = Result<(), Box<dyn std::error::Error>>;

fn main() -> CliResult {
    let args = Args::parse();
    match args.subcommand() {
        Some("serve") => serve(&args),
        Some("loadgen") => loadgen(&args),
        Some("stats") => stats(&args),
        Some("top") => top(&args),
        Some("inspect") => inspect(&args),
        Some("lint") => lint(&args),
        Some("selftest") => selftest(),
        _ => {
            eprintln!(
                "usage: pulse <serve|loadgen|stats|top|inspect|lint|\
                 selftest>\n\
                 serve:   [--app webservice|wiredtiger|btrdb|skiplist|\
                 radixtrie|graph] [--backend pulse|pulse-acc|cache|rpc|\
                 rpc-arm|cache-rpc|live] [--mix a|b|c] [--nodes N] \
                 [--ops N] [--conc N] [--ycsb A|B|C|E] [--window-s S] \
                 [--uniform] [--granularity BYTES] [--loss P] \
                 [--no-in-network] [--hops N]\n\
                 serve --listen ADDR: expose the backend over TCP \
                 (frames: srv/README.md); builds the --mix/--app \
                 structures, serves for --duration-s S (graceful \
                 drain + metrics tables on exit; without it the \
                 process runs until killed — std-only build, no \
                 signal handler, so a kill skips the drain); --conc \
                 sets the admission window; --io-threads N sizes the \
                 event-loop worker pool (0 = auto), --legacy-threads \
                 serves with the old two-threads-per-connection tier; \
                 --read-only rejects REGISTERs of programs that may \
                 write node DRAM; \
                 observability: \
                 [--trace-out PATH [--trace-sample N] [--trace-seed S]] \
                 [--stats-out PATH --stats-interval-s S]\n\
                 stats: --addr ADDR [--raw] [--watch SECS [--count N]] \
                 — poll a live server's metrics registry over a STATS \
                 frame; --watch re-polls every SECS and prints \
                 per-interval counter rates\n\
                 top: --addr ADDR [--interval-s S] [--count N] — live \
                 dashboard: request/response rates, phase-sliced \
                 latency breakdown, per-program e2e, queue depths, \
                 connection ledger\n\
                 loadgen: --addr ADDR [--mix a|b|c | --app skiplist|\
                 radixtrie|graph] [--conns N] [--depth D] [--rate \
                 OPS_PER_S (open loop)] [--keys N] [--ops N] [--seed S] \
                 [--json NAME] [--attribution] [--slow-op-log PATH \
                 [--slow-op-us N]] — rack/workload flags must match \
                 the server's; --attribution negotiates per-request \
                 server timing blocks, --slow-op-log writes JSONL rows \
                 for requests slower than --slow-op-us (0 = all)\n\
                 inspect: [--iter NAME]\n\
                 lint: [--app NAME | --all-scenarios] [--json] — run \
                 the abstract-interpretation analyzer over built-in \
                 scenario programs; exits nonzero on any deny"
            );
            std::process::exit(2);
        }
    }
}

/// The wire-servable workload both `serve --listen` and `loadgen`
/// build: `--mix a|b|c` (hash index YCSB) or a scenario `--app`.
fn serving_spec(args: &Args) -> Result<ServingSpec, String> {
    let workload = match (args.get("mix"), args.get("app")) {
        // the whole serving contract is that server and loadgen agree
        // on ONE workload — an ambiguous flag pair is an error, not a
        // silent precedence rule
        (Some(m), Some(app)) => {
            return Err(format!(
                "--mix {m:?} and --app {app:?} are mutually \
                 exclusive: pick one workload"
            ))
        }
        (Some(m), None) => match m {
            "a" | "A" => "mix-a".to_string(),
            "b" | "B" => "mix-b".to_string(),
            "c" | "C" => "mix-c".to_string(),
            other => {
                return Err(format!("--mix expects a|b|c, got {other:?}"))
            }
        },
        (None, Some(app)) => match app {
            "skiplist" | "radixtrie" | "graph" => app.to_string(),
            other => {
                return Err(format!(
                    "wire serving supports --app skiplist|radixtrie|\
                     graph or --mix a|b|c, got {other:?}"
                ))
            }
        },
        (None, None) => "mix-c".to_string(),
    };
    Ok(ServingSpec {
        workload,
        keys: args.u64_or("keys", 20_000),
        ops: args.u64_or("ops", 4_000),
        zipf: !args.flag("uniform"),
        max_scan: args.usize_or("max-scan", 60),
        max_hops: args
            .u64_or("hops", 8)
            .clamp(1, pulse::ds::graph::MAX_HOPS as u64)
            as u32,
        seed: args.u64_or("seed", 42),
    })
}

/// `pulse serve --listen ADDR`: build the workload's structures on the
/// chosen backend and serve them over TCP until shutdown, then print
/// the serving-tier and backend metrics tables.
fn serve_listen(args: &Args, listen: &str) -> CliResult {
    let kind = args.str_or("backend", "live");
    let mut backend = make_backend(&kind, cfg_from(args));
    let spec = serving_spec(args)?;
    // build the structures; the op stream itself is the client's job
    let _ = build_serving_ops(backend.rack_mut(), &spec);
    let cfg = SrvConfig {
        window: args.usize_or("conc", 64),
        run_secs: args.f64_or("duration-s", 0.0),
        // --stats-out alone implies a 1 Hz sampler; --stats-interval-s
        // alone does nothing (there is nowhere to write rows to)
        stats_interval_s: args.f64_or(
            "stats-interval-s",
            if args.get("stats-out").is_some() { 1.0 } else { 0.0 },
        ),
        trace: args.get("trace-out").map(|_| pulse::obs::TraceConfig {
            sample_every: args.u64_or("trace-sample", 64).max(1),
            seed: args.u64_or("trace-seed", 42),
            ..Default::default()
        }),
        // event-loop runtime tuning: worker count (0 = auto), and the
        // legacy thread-pair tier for A/B comparison
        io_threads: args.usize_or("io-threads", 0),
        legacy_threads: args.flag("legacy-threads"),
        // read-only serving: the analyzer's write-effect inference
        // gates mutating REGISTERs at wire admission
        allow_writes: !args.flag("read-only"),
        ..SrvConfig::default()
    };
    let (mut server, handle) = Server::bind(backend, listen, cfg)?;
    if let Some(p) = args.get("stats-out") {
        server.set_stats_out(p.into());
    }
    eprintln!(
        "pulse srv: listening on {} backend={kind} workload={} \
         keys={} seed={} nodes={} window={}",
        handle.addr(),
        spec.workload,
        spec.keys,
        spec.seed,
        args.usize_or("nodes", 4),
        cfg.window,
    );
    if cfg.run_secs == 0.0 {
        eprintln!(
            "pulse srv: no --duration-s; the process runs until \
             killed, and a kill skips the graceful drain and the \
             exit metrics tables (std-only build: no signal handler \
             to catch Ctrl-C) — pass --duration-s S for a drained, \
             metered exit"
        );
    }
    let summary = server.run();
    if let Some(path) = args.get("trace-out") {
        let t = &summary.engine.trace;
        std::fs::write(path, t.to_jsonl())?;
        let chrome = format!("{path}.chrome.json");
        std::fs::write(&chrome, t.to_chrome())?;
        eprintln!(
            "pulse srv: wrote {} trace spans to {path} (+ {chrome})",
            t.len()
        );
    }
    println!("{}", summary.srv.summary());
    // per-program e2e table (rows exist only when a client negotiated
    // latency attribution)
    if let pulse::util::json::Json::Obj(m) = &summary.registry {
        let g = |k: &str| {
            m.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0)
        };
        for k in m.keys() {
            if let Some(prog) = k
                .strip_prefix("srv.e2e.prog")
                .and_then(|s| s.strip_suffix(".count"))
            {
                println!(
                    "program {prog}: n={:.0} e2e mean={:.1}us \
                     p99={:.1}us exec mean={:.1}us",
                    g(&format!("srv.e2e.prog{prog}.count")),
                    g(&format!("srv.e2e.prog{prog}.mean")) / 1e3,
                    g(&format!("srv.e2e.prog{prog}.p99")) / 1e3,
                    g(&format!("engine.execute.prog{prog}.mean"))
                        / 1e3,
                );
            }
        }
    }
    let b = &summary.backend;
    println!(
        "backend {}: ops={} trapped={} ops/s={:.0} p50={:.1}us \
         p95={:.1}us p99={:.1}us busy={} decode-errors={} dropped={}",
        b.name,
        b.ops,
        b.trapped,
        b.tput_ops_per_s,
        b.p50_latency_ns as f64 / 1e3,
        b.p95_latency_ns as f64 / 1e3,
        b.p99_latency_ns as f64 / 1e3,
        b.wire_busy,
        b.wire_decode_errors,
        b.net_dropped,
    );
    print_live_counters(b);
    println!(
        "serving window: {:.2}s, drain: {:.0}ms \
         (rates are over the serving window only)",
        summary.serving_ms / 1e3,
        summary.drain_ms,
    );
    println!("engine: {}", summary.engine.run.summary());
    Ok(())
}

/// Per-shard dataplane counters (live engine only; all zero on the DES
/// and the model backends, whose equivalents live in the serve report).
fn print_live_counters(b: &pulse::backend::BackendMetrics) {
    if b.live_forwards + b.live_yields + b.live_traps + b.live_drops
        > 0
        || b.live_max_queue_depth > 0
    {
        println!(
            "live shards: forwards={} yields={} traps={} drops={} \
             max-queue-depth={}",
            b.live_forwards,
            b.live_yields,
            b.live_traps,
            b.live_drops,
            b.live_max_queue_depth,
        );
    }
}

/// `pulse stats --addr HOST:PORT`: poll a live server's metrics
/// registry (one STATS frame). Default output is an aligned
/// name/value table; `--raw` prints the snapshot JSON verbatim;
/// `--watch SECS` re-polls on that interval and prints per-interval
/// counter rates (levels like `.p99` and gauges are delta-meaningless
/// and are skipped by `snapshot_rates`).
fn stats(args: &Args) -> CliResult {
    let Some(addr) = args.get("addr") else {
        return Err("stats needs --addr HOST:PORT".into());
    };
    let watch_s = args.f64_or("watch", 0.0);
    if watch_s > 0.0 {
        let count = args.u64_or("count", 0);
        let mut prev = pulse::srv::fetch_stats(addr)?;
        let mut prev_t = std::time::Instant::now();
        let mut rounds = 0u64;
        loop {
            std::thread::sleep(std::time::Duration::from_secs_f64(
                watch_s.max(0.1),
            ));
            let cur = pulse::srv::fetch_stats(addr)?;
            let dt = prev_t.elapsed().as_secs_f64();
            prev_t = std::time::Instant::now();
            let rates = pulse::obs::snapshot_rates(&prev, &cur, dt);
            println!("-- {dt:.1}s window --");
            print_json_table(&rates);
            prev = cur;
            rounds += 1;
            if count > 0 && rounds >= count {
                return Ok(());
            }
        }
    }
    let snap = pulse::srv::fetch_stats(addr)?;
    if args.flag("raw") {
        println!("{}", snap.render());
        return Ok(());
    }
    print_json_table(&snap);
    Ok(())
}

/// Aligned name/value table for a flat snapshot object.
fn print_json_table(snap: &pulse::util::json::Json) {
    match snap {
        pulse::util::json::Json::Obj(m) => {
            let width =
                m.keys().map(|k| k.len()).max().unwrap_or(0);
            for (k, v) in m {
                println!("{k:width$}  {}", v.render());
            }
        }
        other => println!("{}", other.render()),
    }
}

/// `pulse top --addr HOST:PORT`: a small live dashboard over the same
/// STATS frame `pulse stats` polls — request/response rates from
/// consecutive snapshots, the phase-sliced latency breakdown the
/// attribution tier records, per-program e2e histograms, engine queue
/// depths, and the connection ledger.
fn top(args: &Args) -> CliResult {
    let Some(addr) = args.get("addr") else {
        return Err("top needs --addr HOST:PORT".into());
    };
    let interval = args.f64_or("interval-s", 2.0).max(0.1);
    let count = args.u64_or("count", 0);
    let mut prev = pulse::srv::fetch_stats(addr)?;
    let mut prev_t = std::time::Instant::now();
    let mut rounds = 0u64;
    loop {
        std::thread::sleep(std::time::Duration::from_secs_f64(
            interval,
        ));
        let cur = pulse::srv::fetch_stats(addr)?;
        let dt = prev_t.elapsed().as_secs_f64();
        prev_t = std::time::Instant::now();
        render_top(addr, &prev, &cur, dt);
        prev = cur;
        rounds += 1;
        if count > 0 && rounds >= count {
            return Ok(());
        }
    }
}

fn render_top(
    addr: &str,
    prev: &pulse::util::json::Json,
    cur: &pulse::util::json::Json,
    dt: f64,
) {
    use pulse::util::json::Json;
    let rates = pulse::obs::snapshot_rates(prev, cur, dt);
    let num = |j: &Json, k: &str| {
        j.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0)
    };
    let r = |k: &str| num(&rates, &format!("{k}_per_s"));
    let g = |k: &str| num(cur, k);
    // ANSI clear + home: a refreshing dashboard, not a scroll
    print!("\x1b[2J\x1b[H");
    println!("pulse top — {addr} — {dt:.1}s window");
    println!(
        "rates   requests={:.0}/s responses={:.0}/s busy={:.0}/s \
         errors-sent={:.0}/s frames-in={:.0}/s",
        r("srv.requests"),
        r("srv.responses"),
        r("srv.busy"),
        r("srv.errors_sent"),
        r("srv.frames_in"),
    );
    println!(
        "conns   active={:.0} opened={:.0} closed={:.0} \
         accepted={:.0} failed={:.0}",
        g("srv.conns_active"),
        g("srv.conns_opened"),
        g("srv.conns_closed"),
        g("srv.conns_accepted"),
        g("srv.conns_failed"),
    );
    println!("phases (lifetime, us)");
    for (label, base) in [
        ("queue-wait", "engine.phase.queue_wait"),
        ("execute", "engine.phase.execute"),
        ("transit", "engine.phase.transit"),
        ("completion", "srv.phase.completion"),
        ("write", "srv.phase.write"),
    ] {
        let n = g(&format!("{base}.count"));
        if n > 0.0 {
            println!(
                "  {label:<11} mean={:9.1} p99={:9.1} n={:.0}",
                g(&format!("{base}.mean")) / 1e3,
                g(&format!("{base}.p99")) / 1e3,
                n,
            );
        }
    }
    if let Json::Obj(m) = cur {
        let mut qline = format!(
            "queues  inbox={:.0}",
            g("engine.inbox.depth")
        );
        for (k, v) in m {
            if let Some(shard) = k
                .strip_prefix("engine.shard")
                .and_then(|s| s.strip_suffix(".queue_depth"))
            {
                let hwm =
                    g(&format!("engine.shard{shard}.queue_hwm"));
                qline.push_str(&format!(
                    " shard{shard}={:.0}/hwm{hwm:.0}",
                    v.as_f64().unwrap_or(0.0),
                ));
            }
        }
        println!("{qline}");
        let mut any = false;
        for k in m.keys() {
            if let Some(prog) = k
                .strip_prefix("srv.e2e.prog")
                .and_then(|s| s.strip_suffix(".count"))
            {
                if !any {
                    println!("programs (e2e, us)");
                    any = true;
                }
                println!(
                    "  prog{prog:<7} n={:<10.0} mean={:9.1} \
                     p99={:9.1} exec-mean={:9.1}",
                    g(&format!("srv.e2e.prog{prog}.count")),
                    g(&format!("srv.e2e.prog{prog}.mean")) / 1e3,
                    g(&format!("srv.e2e.prog{prog}.p99")) / 1e3,
                    g(&format!("engine.execute.prog{prog}.mean"))
                        / 1e3,
                );
            }
        }
        if !any {
            println!(
                "programs: none attributed (loadgen --attribution \
                 arms per-program histograms)"
            );
        }
    }
}

/// `pulse loadgen`: materialize the workload against a shadow rack and
/// drive a listening server over real sockets.
fn loadgen(args: &Args) -> CliResult {
    let Some(addr) = args.get("addr") else {
        return Err("loadgen needs --addr HOST:PORT".into());
    };
    let spec = serving_spec(args)?;
    let mut shadow = Rack::new(cfg_from(args));
    let ops = build_serving_ops(&mut shadow, &spec);
    let cfg = LoadgenConfig {
        addr: addr.to_string(),
        conns: args.usize_or("conns", 4),
        depth: args.usize_or("depth", 16),
        open_rate: args.f64_or("rate", 0.0),
        // clamp instead of silently wrapping (2^32 would truncate to
        // 0 = "server default", inverting the user's intent); the
        // server clamps further to its own grant × boost bound
        budget: {
            let b = args.u64_or("budget", 0);
            if b > u32::MAX as u64 {
                eprintln!(
                    "pulse loadgen: --budget {b} clamped to {}",
                    u32::MAX
                );
            }
            b.min(u32::MAX as u64) as u32
        },
        record_results: false,
        attribution: args.flag("attribution"),
        slow_op_log: args.get("slow-op-log").map(String::from),
        slow_op_us: args.u64_or("slow-op-us", 1000),
    };
    eprintln!(
        "pulse loadgen: {} -> {} workload={} conns={} depth={} {}",
        ops.len(),
        cfg.addr,
        spec.workload,
        cfg.conns,
        cfg.depth,
        if cfg.open_rate > 0.0 {
            format!("open-loop @ {:.0} ops/s", cfg.open_rate)
        } else {
            "closed-loop".to_string()
        },
    );
    let report = run_loadgen(&cfg, ops)?;
    println!("{}", report.summary());
    if let Some(name) = args.get("json") {
        pulse::bench_support::save_json(name, &report.to_json())?;
    }
    Ok(())
}

fn cfg_from(args: &Args) -> RackConfig {
    let mut cfg = RackConfig {
        nodes: args.usize_or("nodes", 4),
        node_capacity: args.u64_or("node-capacity", 1 << 30),
        granularity: args.u64_or("granularity", 8 << 20),
        loss: args.f64_or("loss", 0.0),
        in_network_routing: !args.flag("no-in-network"),
        seed: args.u64_or("seed", 42),
        ..Default::default()
    };
    cfg.dispatch.cache_bytes = args.u64_or("cache-bytes", 0);
    cfg
}

fn serve(args: &Args) -> CliResult {
    // `--listen ADDR` switches serve from in-process workload replay
    // to the TCP wire tier (srv/): same backends, real sockets
    if let Some(listen) = args.get("listen") {
        let listen = listen.to_string();
        return serve_listen(args, &listen);
    }
    let app_name = args.str_or("app", "webservice");
    let kind = args.str_or("backend", "pulse");
    let ops_n = args.u64_or("ops", 2_000);
    let conc = args.usize_or("conc", 32);
    let zipf = !args.flag("uniform");
    let seed = args.u64_or("seed", 42);
    // any compared system behind the unified trait: the rack DES
    // (pulse/pulse-acc), the model baselines, or the live
    // multi-threaded engine (one real worker thread per memory node)
    let mut backend = make_backend(&kind, cfg_from(args));

    // mixed read-write serving (`--mix a|b`): YCSB-A/B over the hash
    // index with offloaded put-on-existing-key updates — the write-path
    // workload, independent of `--app`
    if let Some(mix) = args.get("mix") {
        let spec = match mix {
            "a" | "A" => YcsbSpec::A,
            "b" | "B" => YcsbSpec::B,
            // read-only control over the same index (the wire tier's
            // default workload, here for in-process comparison)
            "c" | "C" => YcsbSpec::C,
            other => {
                return Err(
                    format!("--mix expects a|b|c, got {other:?}").into()
                )
            }
        };
        let wspec = WriteMixSpec {
            keys: args.u64_or("keys", 20_000),
            ops: ops_n,
            zipf,
            seed,
        };
        let ops = build_write_mix_ops(backend.rack_mut(), spec, &wspec);
        let report = backend.serve_batch(&ops, conc);
        print_report(
            &format!("{} write-mix", spec.name()),
            backend.as_mut(),
            conc,
            &report,
        );
        return Ok(());
    }

    let report = match app_name.as_str() {
        "webservice" => {
            let users = args.u64_or("keys", 5_000);
            let spec = match args.str_or("ycsb", "B").as_str() {
                "A" => YcsbSpec::A,
                "C" => YcsbSpec::C,
                _ => YcsbSpec::B,
            };
            let app =
                WebServiceApp::build(backend.rack_mut(), users, seed);
            let w = YcsbWorkload::new(spec, users, zipf, seed ^ 1);
            let mut ops = app.op_stream(w, ops_n);
            backend.serve(&mut |i| ops(i), conc)
        }
        "wiredtiger" => {
            let keys = args.u64_or("keys", 100_000);
            let app =
                WiredTigerApp::build(backend.rack_mut(), keys, seed);
            let w = YcsbWorkload::new(YcsbSpec::E, keys, zipf, seed ^ 1)
                .with_max_scan(args.usize_or("max-scan", 100));
            let mut ops = app.op_stream(w, ops_n);
            backend.serve(&mut |i| ops(i), conc)
        }
        "btrdb" => {
            let samples = args.usize_or("keys", 60_000);
            let app =
                BtrDbApp::build(backend.rack_mut(), samples, seed);
            let win = args.u64_or("window-s", 1) as i64 * SEC;
            let mut ops = app.op_stream(win, ops_n, seed ^ 1);
            backend.serve(&mut |i| ops(i), conc)
        }
        // scenario-expansion apps: skiplist (YCSB-E scans), radixtrie
        // (YCSB-C lookups), graph (bounded k-hop walks). The workload
        // builder is shared with benches/scenarios.rs, so the CLI
        // serves exactly the stream BENCH_scenarios.json reports.
        "skiplist" | "radixtrie" | "graph" => {
            let which = match app_name.as_str() {
                "skiplist" => "skiplist-e",
                "radixtrie" => "trie-lookup",
                _ => "graph-khop",
            };
            let spec = ScenarioSpec {
                keys: args.u64_or("keys", 20_000),
                ops: ops_n,
                zipf,
                max_scan: args.usize_or("max-scan", 60),
                // clamp instead of letting the generator's assert panic
                max_hops: args
                    .u64_or("hops", 8)
                    .clamp(1, pulse::ds::graph::MAX_HOPS as u64)
                    as u32,
                seed,
                ..Default::default()
            };
            let ops =
                build_scenario_ops(backend.rack_mut(), which, &spec);
            backend.serve(&mut |i| ops.get(i as usize).cloned(), conc)
        }
        other => return Err(format!("unknown app {other:?}").into()),
    };

    print_report(&app_name, backend.as_mut(), conc, &report);
    Ok(())
}

fn print_report(
    app_label: &str,
    backend: &mut dyn pulse::backend::TraversalBackend,
    conc: usize,
    report: &pulse::rack::ServeReport,
) {
    let (p50, p95, p99) = report.latency_percentiles();
    println!(
        "app={app_label} backend={} nodes={} ops={} conc={conc}",
        backend.name(),
        backend.rack_mut().cfg.nodes,
        report.completed
    );
    println!(
        "latency: p50={:.1}us p95={:.1}us p99={:.1}us mean={:.1}us",
        p50 as f64 / 1e3,
        p95 as f64 / 1e3,
        p99 as f64 / 1e3,
        report.latency.mean() / 1e3
    );
    println!(
        "throughput: {:.0} ops/s  (makespan {:.2} ms, {:.0} ms wall)",
        report.tput_ops_per_s,
        report.makespan_ns as f64 / 1e6,
        report.wall_ms
    );
    println!(
        "iters/op={:.1} cross-node-reqs={} retransmits={} traps={}",
        report.total_iters as f64 / report.completed.max(1) as f64,
        report.cross_node_requests,
        report.retransmits,
        report.trapped
    );
    // the DES routes through the rack's switch model; the live engine
    // and the trace-replay baselines keep their own routing counters,
    // so only print the switch line when it actually saw traffic
    let sw = backend.rack_mut().switch.stats;
    if sw.routed_requests > 0 {
        println!(
            "switch: routed={} reroutes={}",
            sw.routed_requests, sw.reroutes
        );
    }
    // link-layer loss is absorbed by retransmission, so it only shows
    // up if surfaced explicitly — overload must be observable
    let m = backend.metrics();
    if m.net_dropped > 0 {
        println!("links: dropped={} (retransmitted)", m.net_dropped);
    }
    print_live_counters(&m);
}

/// Look a built-in scenario iterator up by CLI name (the shared
/// `ds::builtin_iters` registry), with a name listing on miss.
fn named_iter(
    name: &str,
) -> Result<pulse::compiler::CompiledIter, Box<dyn std::error::Error>> {
    let mut all = pulse::ds::builtin_iters();
    if let Some(pos) = all.iter().position(|(n, _)| *n == name) {
        return Ok(all.swap_remove(pos).1);
    }
    let names: Vec<&str> = all.iter().map(|(n, _)| *n).collect();
    Err(format!(
        "unknown iterator {name:?} (try one of: {})",
        names.join(", ")
    )
    .into())
}

fn inspect(args: &Args) -> CliResult {
    let name = args.str_or("iter", "list-find");
    let iter = named_iter(&name)?;
    println!(
        "{name}: {} instructions, loads {} words/iteration{}",
        iter.program.len(),
        iter.program.load_words,
        if iter.program.writes_data { ", writes back" } else { "" }
    );
    println!(
        "t_c={:.0}ns t_d={:.0}ns ratio={:.2} -> {}",
        iter.t_c_ns,
        iter.t_d_ns,
        iter.ratio(),
        if iter.offloadable(0.75) {
            "OFFLOAD (t_c <= 0.75 t_d)"
        } else {
            "CPU fallback"
        }
    );
    for (pc, i) in iter.program.instrs.iter().enumerate() {
        println!("  {pc:2}: {i}");
    }
    Ok(())
}

/// `pulse lint` — run the abstract-interpretation analyzer
/// (`isa::analyze`) over built-in scenario programs and report every
/// diagnostic. The third enforcement layer (compile → wire admission →
/// **lint**): CI runs `pulse lint --all-scenarios --json` and fails
/// the build on any deny-severity finding.
fn lint(args: &Args) -> CliResult {
    use pulse::util::json::Json;

    let iters = if let Some(name) = args.get("app") {
        vec![(String::from(name), named_iter(name)?)]
    } else {
        // `--all-scenarios` is also the default when no --app is given
        pulse::ds::builtin_iters()
            .into_iter()
            .map(|(n, it)| (n.to_string(), it))
            .collect()
    };

    let mut denies = 0usize;
    let mut warns = 0usize;
    let mut rows = Vec::new();
    for (name, iter) in &iters {
        let a = pulse::isa::analyze(&iter.program, iter.sp_inputs);
        let deny =
            a.diags.iter().filter(|d| {
                d.severity == pulse::isa::Severity::Deny
            }).count();
        let warn = a.diags.len() - deny;
        denies += deny;
        warns += warn;
        if args.flag("json") {
            let mut row = Json::obj();
            row.set("scenario", name.as_str());
            row.set("instructions", iter.program.len());
            row.set("writes_dram", a.writes_dram);
            row.set("trap_free", a.trap_free);
            row.set("deny", deny);
            row.set("warn", warn);
            row.set(
                "diags",
                a.diags
                    .iter()
                    .map(|d| Json::from(d.to_string()))
                    .collect::<Vec<Json>>(),
            );
            rows.push(row);
        } else {
            println!(
                "{name}: {} instructions, {} deny, {} warn{}{}",
                iter.program.len(),
                deny,
                warn,
                if a.writes_dram { ", writes DRAM" } else { "" },
                if a.trap_free { ", trap-free" } else { "" },
            );
            for d in &a.diags {
                println!("  {d}");
            }
        }
    }
    if args.flag("json") {
        let mut out = Json::obj();
        out.set("scenarios", rows);
        out.set("deny", denies);
        out.set("warn", warns);
        println!("{}", out.render());
    } else {
        println!(
            "lint: {} scenario(s), {denies} deny, {warns} warn",
            iters.len()
        );
    }
    if denies > 0 {
        return Err(format!(
            "lint failed: {denies} deny-severity diagnostic(s)"
        )
        .into());
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn selftest() -> CliResult {
    use pulse::interp::logic_pass;
    use pulse::runtime::PjrtRuntime;
    use pulse::util::prng::Rng;

    let rt = PjrtRuntime::new(PjrtRuntime::default_dir())?;
    let exe = rt.load_logic_step(32)?;
    let mut rng = Rng::new(0xDEC0DE);
    for case in 0..20 {
        let p = pulse::testgen::random_verified_program(&mut rng, 24);
        let mut xla: Vec<_> = (0..32)
            .map(|_| pulse::testgen::random_workspace(&mut rng))
            .collect();
        let mut native = xla.clone();
        let st = exe.run(&p, &mut xla)?;
        for (i, w) in native.iter_mut().enumerate() {
            let r = logic_pass(&p, w);
            if st[i] != r.status {
                return Err(
                    format!("case {case} lane {i}: status diverged").into()
                );
            }
        }
        if xla != native {
            return Err(format!("case {case}: workspace diverged").into());
        }
    }
    println!("selftest OK: XLA artifact = native interpreter (20 cases x 32 lanes)");
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn selftest() -> CliResult {
    println!(
        "selftest: the PJRT/XLA runtime path is disabled in this build; \
         rebuild with `--features xla` (requires the vendored xla-rs \
         crate and `make artifacts`) to verify the AOT artifacts against \
         the native interpreter."
    );
    Ok(())
}
