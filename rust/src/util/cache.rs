//! Cache-line padding for cross-thread counters and slot tables.
//!
//! `#[repr(align(64))]` forces each wrapped value onto its own cache
//! line (64 B on every x86-64 / mainstream aarch64 part), so two
//! threads hammering *adjacent* counters — a shard queue's producer
//! and consumer sides, neighbouring routing counters, per-shard slot
//! entries — never ping-pong one line between cores (false sharing).
//! The wrapper is transparent via `Deref`/`DerefMut`: call sites read
//! and bump the inner value exactly as before.

use std::ops::{Deref, DerefMut};

/// Pads (and aligns) `T` to a 64-byte cache line.
#[derive(Debug, Default)]
#[repr(align(64))]
pub struct CachePadded<T> {
    value: T,
}

impl<T> CachePadded<T> {
    pub const fn new(value: T) -> Self {
        Self { value }
    }

    pub fn into_inner(self) -> T {
        self.value
    }
}

impl<T> Deref for CachePadded<T> {
    type Target = T;

    fn deref(&self) -> &T {
        &self.value
    }
}

impl<T> DerefMut for CachePadded<T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.value
    }
}

impl<T> From<T> for CachePadded<T> {
    fn from(value: T) -> Self {
        Self::new(value)
    }
}

impl<T: Clone> Clone for CachePadded<T> {
    fn clone(&self) -> Self {
        Self::new(self.value.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn aligned_and_sized_to_a_cache_line() {
        assert_eq!(std::mem::align_of::<CachePadded<u8>>(), 64);
        assert_eq!(std::mem::size_of::<CachePadded<u8>>(), 64);
        assert_eq!(std::mem::size_of::<CachePadded<AtomicU64>>(), 64);
        // arrays of padded slots put each element on its own line
        let slots: [CachePadded<AtomicU64>; 4] = Default::default();
        for w in slots.windows(2) {
            let a = &*w[0] as *const AtomicU64 as usize;
            let b = &*w[1] as *const AtomicU64 as usize;
            assert!(b - a >= 64);
        }
    }

    #[test]
    fn transparent_access() {
        let c = CachePadded::new(AtomicU64::new(1));
        c.fetch_add(2, Ordering::Relaxed);
        assert_eq!(c.load(Ordering::Relaxed), 3);
        assert_eq!(c.into_inner().into_inner(), 3);
    }
}
