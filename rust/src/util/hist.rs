//! Latency histogram with log-spaced buckets (HdrHistogram-lite).
//!
//! Records nanosecond values; reports count/mean/percentiles. Used by
//! the metrics layer for p50/p99 latency and by the bench harness.

#[derive(Debug, Clone)]
pub struct Histogram {
    /// buckets[i] counts values in [lo_of(i), lo_of(i+1)).
    /// Layout: 64 "decades" of 16 sub-buckets each (log2 major, linear
    /// minor) — <5% relative error, fixed 1024 slots.
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: u64,
    max: u64,
}

const SUB: usize = 16;
const SUB_SHIFT: u32 = 4;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Total bucket slots (the layout contract shared with
    /// `obs::AtomicHist`, which mirrors this layout in atomics).
    pub const SLOTS: usize = 64 * SUB;

    pub fn new() -> Self {
        Self {
            buckets: vec![0; 64 * SUB],
            count: 0,
            sum: 0.0,
            min: u64::MAX,
            max: 0,
        }
    }

    /// Bucket slot for value `v` (public for `obs::AtomicHist`).
    #[inline]
    pub fn index_of(v: u64) -> usize {
        Self::index(v)
    }

    /// Rebuild a histogram from raw layout-compatible parts (the
    /// `obs::AtomicHist` snapshot path). `buckets.len()` must be
    /// [`Self::SLOTS`]; an empty histogram must pass `min: u64::MAX`.
    pub fn from_raw(
        buckets: Vec<u64>,
        count: u64,
        sum: f64,
        min: u64,
        max: u64,
    ) -> Self {
        assert_eq!(buckets.len(), Self::SLOTS, "bucket layout mismatch");
        Self { buckets, count, sum, min, max }
    }

    #[inline]
    fn index(v: u64) -> usize {
        if v < SUB as u64 {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros();
        let major = (msb - SUB_SHIFT + 1) as usize;
        let minor = (v >> (msb - SUB_SHIFT)) as usize & (SUB - 1);
        // major decade 0 covers [0,16): handled above.
        (major * SUB + minor).min(64 * SUB - 1)
    }

    /// Lower bound of bucket i (representative value ≈ midpoint).
    fn bucket_mid(i: usize) -> u64 {
        let major = i / SUB;
        let minor = (i % SUB) as u64;
        if major == 0 {
            return minor;
        }
        let base = 1u64 << (major as u32 + SUB_SHIFT - 1);
        let width = base / SUB as u64;
        base + minor * width + width / 2
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.buckets[Self::index(v)] += 1;
        self.count += 1;
        self.sum += v as f64;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn record_n(&mut self, v: u64, n: u64) {
        self.buckets[Self::index(v)] += n;
        self.count += n;
        self.sum += v as f64 * n as f64;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.max
        }
    }

    /// q in [0,1]; returns an approximate quantile value. Exact at the
    /// edges: q = 1.0 reports the true maximum (not the midpoint of the
    /// last occupied bucket), and a single-sample histogram reports its
    /// sample for every q.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        if self.count == 1 || self.min == self.max {
            return self.min;
        }
        if q >= 1.0 {
            return self.max;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let target = target.max(1);
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                // all remaining samples are in this bucket: the true
                // maximum is a better representative than bucket_mid
                // (which can over- or under-shoot past it)
                if seen == self.count && target == self.count {
                    return self.max;
                }
                return Self::bucket_mid(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1} p50={} p99={} min={} max={}",
            self.count,
            self.mean(),
            self.p50(),
            self.p99(),
            self.min(),
            self.max()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p50(), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile(q), 0, "q={q}");
        }
    }

    #[test]
    fn single_value() {
        let mut h = Histogram::new();
        h.record(1000);
        assert_eq!(h.count(), 1);
        assert_eq!(h.min(), 1000);
        assert_eq!(h.max(), 1000);
        // a one-sample histogram reports its sample exactly at every q
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(h.quantile(q), 1000, "q={q}");
        }
    }

    #[test]
    fn q_one_reports_true_max() {
        let mut h = Histogram::new();
        // large values land in wide buckets where bucket_mid drifts
        // from the recorded extreme; q=1.0 must still be exact
        for v in [1_000_003u64, 1_000_777, 1_048_575] {
            h.record(v);
        }
        assert_eq!(h.quantile(1.0), 1_048_575);
        assert!(h.p50() >= h.min() && h.p50() <= h.max());
    }

    #[test]
    fn all_in_one_bucket() {
        let mut h = Histogram::new();
        // identical values: every quantile is that value
        for _ in 0..100 {
            h.record(4242);
        }
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile(q), 4242, "q={q}");
        }
        assert_eq!(h.min(), 4242);
        assert_eq!(h.max(), 4242);
    }

    #[test]
    fn quantiles_of_uniform_ramp() {
        let mut h = Histogram::new();
        for v in 1..=10_000u64 {
            h.record(v);
        }
        let p50 = h.p50() as f64;
        let p95 = h.p95() as f64;
        let p99 = h.p99() as f64;
        assert!((p50 - 5000.0).abs() / 5000.0 < 0.1, "p50 {p50}");
        assert!((p95 - 9500.0).abs() / 9500.0 < 0.1, "p95 {p95}");
        assert!((p99 - 9900.0).abs() / 9900.0 < 0.1, "p99 {p99}");
        assert!((h.mean() - 5000.5).abs() < 1.0);
    }

    #[test]
    fn wide_dynamic_range() {
        let mut h = Histogram::new();
        h.record(1);
        h.record(1_000_000_000);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 1_000_000_000);
    }

    #[test]
    fn merge_adds() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for v in 0..100 {
            a.record(v);
            b.record(v + 100);
        }
        a.merge(&b);
        assert_eq!(a.count(), 200);
        assert_eq!(a.max(), 199);
    }

    #[test]
    fn relative_error_bounded() {
        for &v in &[17u64, 100, 999, 12345, 7_000_000, 123_456_789] {
            let mut h = Histogram::new();
            h.record(v);
            let got = Histogram::bucket_mid(Histogram::index(v));
            let err = (got as f64 - v as f64).abs() / v as f64;
            assert!(err < 0.07, "v={v} got={got} err={err}");
        }
    }
}
