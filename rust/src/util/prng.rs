//! Deterministic PRNGs: SplitMix64 (seeding) and xoshiro256** (streams).
//!
//! Every stochastic component in the simulator (workload choosers,
//! allocators, loss injection) takes an explicit `Rng` so experiments are
//! reproducible from a single seed.

/// SplitMix64 — used to expand a single u64 seed into stream state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality 64-bit generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed a stream; distinct `stream` values give independent streams.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut sm = seed ^ stream.wrapping_mul(0xA3EC647659359ACD);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = splitmix64(&mut sm);
        }
        // All-zero state is invalid for xoshiro; splitmix cannot produce
        // four zeros from any seed, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` (n > 0), Lemire's unbiased method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Uniform i64 over the full range.
    #[inline]
    pub fn next_i64(&mut self) -> i64 {
        self.next_u64() as i64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (used by the µPMU generator).
    pub fn next_normal(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 0.0 {
                let v = self.next_f64();
                let r = (-2.0 * u.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_streams_differ() {
        let mut a = Rng::with_stream(42, 0);
        let mut b = Rng::with_stream(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
