//! Minimal CLI argument parser (the offline registry has no `clap`).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! args, and subcommands. Used by the `pulse` launcher and every bench
//! binary.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable).
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Self {
        let mut out = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse the process args (skipping argv[0]).
    pub fn parse() -> Self {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{name} expects an integer, got {v:?}")
                })
            })
            .unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.u64_or(name, default as u64) as usize
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{name} expects a float, got {v:?}")
                })
            })
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn key_value_forms() {
        let a = parse("serve --nodes 4 --eta=0.75 --verbose");
        assert_eq!(a.subcommand(), Some("serve"));
        assert_eq!(a.u64_or("nodes", 1), 4);
        assert!((a.f64_or("eta", 1.0) - 0.75).abs() < 1e-9);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.u64_or("nodes", 2), 2);
        assert_eq!(a.str_or("app", "webservice"), "webservice");
    }

    #[test]
    fn flag_before_value_opt() {
        let a = parse("--dry-run --seed 7");
        assert!(a.flag("dry-run"));
        assert_eq!(a.u64_or("seed", 0), 7);
    }

    #[test]
    fn negative_number_value() {
        let a = parse("--shift=-3");
        assert_eq!(a.get("shift"), Some("-3"));
    }
}
