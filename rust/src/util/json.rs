//! Tiny JSON writer + minimal reader (offline registry has no `serde`).
//!
//! The writer is used by benches to emit machine-readable results under
//! `bench_out/`; the reader is just enough to parse
//! `artifacts/manifest.json` (flat objects of strings/numbers).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// JSON value (writer side).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, v: impl Into<Json>) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), v.into());
        } else {
            panic!("set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                out.push_str(if *b { "true" } else { "false" })
            }
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Minimal recursive-descent parser.
    pub fn parse(s: &str) -> Result<Json, String> {
        let bytes = s.as_bytes();
        let mut pos = 0;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing data at {pos}"));
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && b[*pos].is_ascii_whitespace() {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end".into());
    }
    match b[*pos] {
        b'{' => {
            *pos += 1;
            let mut m = BTreeMap::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(m));
            }
            loop {
                skip_ws(b, pos);
                let k = match parse_value(b, pos)? {
                    Json::Str(s) => s,
                    _ => return Err("object key must be string".into()),
                };
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected ':' at {pos}"));
                }
                *pos += 1;
                let v = parse_value(b, pos)?;
                m.insert(k, v);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => {
                        *pos += 1;
                    }
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(m));
                    }
                    _ => return Err(format!("expected ',' or '}}' at {pos}")),
                }
            }
        }
        b'[' => {
            *pos += 1;
            let mut xs = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(xs));
            }
            loop {
                xs.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => {
                        *pos += 1;
                    }
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(xs));
                    }
                    _ => return Err(format!("expected ',' or ']' at {pos}")),
                }
            }
        }
        b'"' => {
            *pos += 1;
            let mut s = String::new();
            while *pos < b.len() {
                match b[*pos] {
                    b'"' => {
                        *pos += 1;
                        return Ok(Json::Str(s));
                    }
                    b'\\' => {
                        *pos += 1;
                        match b.get(*pos) {
                            Some(b'n') => s.push('\n'),
                            Some(b't') => s.push('\t'),
                            Some(b'r') => s.push('\r'),
                            Some(b'"') => s.push('"'),
                            Some(b'\\') => s.push('\\'),
                            Some(b'/') => s.push('/'),
                            Some(b'u') => {
                                let hex = std::str::from_utf8(
                                    &b[*pos + 1..*pos + 5],
                                )
                                .map_err(|_| "bad \\u".to_string())?;
                                let cp = u32::from_str_radix(hex, 16)
                                    .map_err(|_| "bad \\u".to_string())?;
                                s.push(
                                    char::from_u32(cp)
                                        .ok_or("bad codepoint")?,
                                );
                                *pos += 4;
                            }
                            _ => return Err("bad escape".into()),
                        }
                        *pos += 1;
                    }
                    c => {
                        // copy UTF-8 bytes verbatim
                        let start = *pos;
                        let len = utf8_len(c);
                        s.push_str(
                            std::str::from_utf8(&b[start..start + len])
                                .map_err(|_| "bad utf8".to_string())?,
                        );
                        *pos += len;
                    }
                }
            }
            Err("unterminated string".into())
        }
        b't' => {
            expect(b, pos, "true")?;
            Ok(Json::Bool(true))
        }
        b'f' => {
            expect(b, pos, "false")?;
            Ok(Json::Bool(false))
        }
        b'n' => {
            expect(b, pos, "null")?;
            Ok(Json::Null)
        }
        _ => {
            let start = *pos;
            while *pos < b.len()
                && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            {
                *pos += 1;
            }
            std::str::from_utf8(&b[start..*pos])
                .ok()
                .and_then(|s| s.parse::<f64>().ok())
                .map(Json::Num)
                .ok_or_else(|| format!("bad number at {start}"))
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn expect(b: &[u8], pos: &mut usize, lit: &str) -> Result<(), String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("expected {lit} at {pos}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let mut j = Json::obj();
        j.set("name", "pulse").set("nodes", 4u64).set("eta", 0.75);
        j.set("flags", Json::Arr(vec![Json::Bool(true), Json::Null]));
        let s = j.render();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn parse_manifest_like() {
        let s = r#"{"isa": {"nreg": 16, "max_instrs": 64},
                    "artifacts": {"a.hlo.txt": {"kind": "logic_step",
                    "batch": 32}}}"#;
        let j = Json::parse(s).unwrap();
        assert_eq!(
            j.get("isa").unwrap().get("nreg").unwrap().as_f64(),
            Some(16.0)
        );
        assert_eq!(
            j.get("artifacts")
                .unwrap()
                .get("a.hlo.txt")
                .unwrap()
                .get("kind")
                .unwrap()
                .as_str(),
            Some("logic_step")
        );
    }

    #[test]
    fn string_escapes() {
        let j = Json::Str("a\"b\\c\nd".into());
        let s = j.render();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn negative_and_float_numbers() {
        let j = Json::parse("[-3, 2.5, 1e3]").unwrap();
        if let Json::Arr(v) = j {
            assert_eq!(v[0].as_f64(), Some(-3.0));
            assert_eq!(v[1].as_f64(), Some(2.5));
            assert_eq!(v[2].as_f64(), Some(1000.0));
        } else {
            panic!()
        }
    }
}
