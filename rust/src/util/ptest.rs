//! Tiny property-test driver (offline registry has no `proptest`).
//!
//! `run_prop` executes a closure over many seeded cases; on failure it
//! retries with a bisection-style shrink over the case index and reports
//! the failing seed so the case is reproducible.

use super::prng::Rng;

/// Iteration-count multiplier for the randomized suites. CI's default
/// job runs at 1× with pinned seeds; the nightly job exports
/// `PULSE_TEST_SCALE=10` for a 10× deep soak (same seeds, more
/// streams). Anything unparsable or < 1 falls back to 1.
pub fn test_scale() -> u64 {
    std::env::var("PULSE_TEST_SCALE")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .filter(|&v| v >= 1)
        .unwrap_or(1)
}

/// Run `cases` property evaluations (× [`test_scale`]). The property
/// receives a fresh `Rng` seeded from (`seed`, case index) and returns
/// `Err(msg)` on violation.
pub fn run_prop<F>(name: &str, seed: u64, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let cases = cases * test_scale();
    for case in 0..cases {
        let mut rng = Rng::with_stream(seed, case);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property `{name}` failed on case {case} \
                 (reproduce with seed={seed}, stream={case}): {msg}"
            );
        }
    }
}

/// Helper: assert_eq for property bodies.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr, $($ctx:tt)*) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a), stringify!($b), a, b
            ) + &format!(": {}", format_args!($($ctx)*)));
        }
    }};
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a), stringify!($b), a, b
            ));
        }
    }};
}

/// Helper: boolean assertion for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($ctx:tt)*) => {{
        if !$cond {
            return Err(format!(
                "assertion failed: {}: {}",
                stringify!($cond), format_args!($($ctx)*)
            ));
        }
    }};
    ($cond:expr) => {{
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run_prop("trivial", 1, 50, |rng| {
            count += 1;
            let v = rng.below(10);
            if v < 10 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property `failing`")]
    fn failing_property_panics_with_seed() {
        run_prop("failing", 1, 50, |rng| {
            if rng.below(10) < 9 {
                Ok(())
            } else {
                Err("hit the 10% case".into())
            }
        });
    }

    #[test]
    fn macros_work() {
        fn body() -> Result<(), String> {
            prop_assert_eq!(1 + 1, 2);
            prop_assert!(3 > 2, "math holds");
            Ok(())
        }
        assert!(body().is_ok());
    }
}
