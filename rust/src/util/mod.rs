//! Infrastructure utilities built in-repo (the offline registry has no
//! `rand`/`clap`/`serde`/`criterion`/`proptest`; see DESIGN.md §2).

pub mod cache;
pub mod cli;
pub mod hist;
pub mod json;
pub mod prng;
pub mod ptest;
pub mod zipf;

pub use cache::CachePadded;
