//! Zipfian key chooser (YCSB-style) + uniform chooser.
//!
//! Implements the Gray et al. "quick" Zipfian generator used by YCSB
//! (`ZipfianGenerator`), including the scrambled variant that spreads the
//! hot keys across the keyspace, and a plain uniform chooser. The paper's
//! workloads (§6) use YCSB A/B/C/E with Zipf(0.99) and a uniform
//! sensitivity study (Appendix Fig. 6).

use super::prng::Rng;

pub const YCSB_ZIPFIAN_CONSTANT: f64 = 0.99;

/// Distribution over `[0, n)` item ranks.
#[derive(Debug, Clone)]
pub enum KeyChooser {
    Uniform { n: u64 },
    Zipfian(Zipfian),
    ScrambledZipfian { inner: Zipfian, n: u64 },
}

impl KeyChooser {
    pub fn uniform(n: u64) -> Self {
        KeyChooser::Uniform { n }
    }

    pub fn zipfian(n: u64) -> Self {
        KeyChooser::Zipfian(Zipfian::new(n, YCSB_ZIPFIAN_CONSTANT))
    }

    /// YCSB default: zipfian ranks scrambled over the keyspace with an
    /// FNV-style hash so "hot" keys are not clustered.
    pub fn scrambled_zipfian(n: u64) -> Self {
        KeyChooser::ScrambledZipfian { inner: Zipfian::new(n, YCSB_ZIPFIAN_CONSTANT), n }
    }

    pub fn n(&self) -> u64 {
        match self {
            KeyChooser::Uniform { n } => *n,
            KeyChooser::Zipfian(z) => z.n,
            KeyChooser::ScrambledZipfian { n, .. } => *n,
        }
    }

    pub fn next(&self, rng: &mut Rng) -> u64 {
        match self {
            KeyChooser::Uniform { n } => rng.below(*n),
            KeyChooser::Zipfian(z) => z.next(rng),
            KeyChooser::ScrambledZipfian { inner, n } => {
                let rank = inner.next(rng);
                fnv1a_64(rank) % n
            }
        }
    }
}

#[inline]
pub fn fnv1a_64(v: u64) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325;
    for b in v.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001B3);
    }
    h
}

/// Gray et al. Zipfian over `[0, n)`; rank 0 is the hottest.
#[derive(Debug, Clone)]
pub struct Zipfian {
    n: u64,
    theta: f64,
    alpha: f64,
    zetan: f64,
    eta: f64,
    zeta2: f64,
}

impl Zipfian {
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0);
        let zetan = Self::zeta(n, theta);
        let zeta2 = Self::zeta(2, theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta))
            / (1.0 - zeta2 / zetan);
        Self { n, theta, alpha, zetan, eta, zeta2: zeta2 }
    }

    fn zeta(n: u64, theta: f64) -> f64 {
        // Exact for small n; Euler–Maclaurin tail approximation for large
        // n keeps construction O(1)-ish while staying within float noise
        // of the exact sum (YCSB uses the exact sum; the approximation
        // error is < 1e-9 relative for n >= 1e6).
        const EXACT_LIMIT: u64 = 1_000_000;
        let m = n.min(EXACT_LIMIT);
        let mut sum = 0.0;
        for i in 1..=m {
            sum += 1.0 / (i as f64).powf(theta);
        }
        if n > m {
            // integral tail: sum_{m+1..n} x^-theta ≈ (n^(1-θ) - m^(1-θ))/(1-θ)
            let a = 1.0 - theta;
            sum += ((n as f64).powf(a) - (m as f64).powf(a)) / a
                + 0.5 * ((n as f64).powf(-theta) - (m as f64).powf(-theta));
        }
        sum
    }

    pub fn next(&self, rng: &mut Rng) -> u64 {
        let u = rng.next_f64();
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let v = (self.n as f64
            * (self.eta * u - self.eta + 1.0).powf(self.alpha))
            as u64;
        v.min(self.n - 1)
    }

    /// Expected probability of the hottest item (diagnostics).
    pub fn p_top(&self) -> f64 {
        1.0 / self.zetan
    }

    #[allow(dead_code)]
    fn zeta2(&self) -> f64 {
        self.zeta2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_is_skewed() {
        let z = Zipfian::new(10_000, YCSB_ZIPFIAN_CONSTANT);
        let mut rng = Rng::new(1);
        let mut top10 = 0usize;
        let trials = 50_000;
        for _ in 0..trials {
            if z.next(&mut rng) < 10 {
                top10 += 1;
            }
        }
        let frac = top10 as f64 / trials as f64;
        // Zipf(0.99) over 10k keys: top-10 take a large chunk (~30-40%).
        assert!(frac > 0.25, "top-10 fraction {frac}");
    }

    #[test]
    fn zipf_in_range() {
        let z = Zipfian::new(100, 0.99);
        let mut rng = Rng::new(2);
        for _ in 0..10_000 {
            assert!(z.next(&mut rng) < 100);
        }
    }

    #[test]
    fn uniform_is_flat() {
        let c = KeyChooser::uniform(100);
        let mut rng = Rng::new(3);
        let mut counts = [0u32; 100];
        for _ in 0..100_000 {
            counts[c.next(&mut rng) as usize] += 1;
        }
        let (mn, mx) = (
            *counts.iter().min().unwrap(),
            *counts.iter().max().unwrap(),
        );
        assert!(mx < 2 * mn, "min {mn} max {mx}");
    }

    #[test]
    fn scrambled_spreads_hot_keys() {
        let c = KeyChooser::scrambled_zipfian(10_000);
        let mut rng = Rng::new(4);
        let mut lows = 0;
        for _ in 0..10_000 {
            if c.next(&mut rng) < 100 {
                lows += 1;
            }
        }
        // After scrambling, the low key range should hold ~1% of mass,
        // not the Zipf head.
        assert!(lows < 800, "lows {lows}");
    }

    #[test]
    fn zeta_tail_approximation_close() {
        let exact = Zipfian::zeta(1_000_000, 0.99);
        let with_tail = Zipfian::zeta(2_000_000, 0.99);
        assert!(with_tail > exact);
        // spot value: zeta(1e6, 0.99) ≈ 15.39 (direct summation)
        assert!((exact - 15.39).abs() < 0.1, "zeta {exact}");
    }
}
