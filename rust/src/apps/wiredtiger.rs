//! WiredTiger-like storage engine (paper §6: MongoDB's backend, B+Tree
//! NoSQL index, YCSB-E range queries over 8 B keys / 240 B values).
//!
//! Records live as 240 B blobs in disaggregated memory; the B+Tree maps
//! key → record address. A YCSB-E scan is the two-stage offload chain:
//! locate-traversal to the covering leaf, then the scan-traversal
//! emitting record addresses into the scratchpad (with continuation
//! rounds for long scans), then the record payloads ride back
//! (`object_read_bytes`).

use crate::ds::bplustree::BPlusTree;
use crate::ds::SP_KEY;
use crate::isa::SP_WORDS;
use crate::mem::GAddr;
use crate::rack::{Op, Rack, Stage};
use crate::util::prng::Rng;
use crate::workloads::{YcsbOp, YcsbWorkload};

use super::WorkloadProfile;

pub const RECORD_BYTES: usize = 240;

pub struct WiredTigerApp {
    pub tree: BPlusTree,
    pub keys: u64,
}

impl WiredTigerApp {
    pub fn build(rack: &mut Rack, keys: u64, seed: u64) -> Self {
        let mut rng = Rng::with_stream(seed, 0x717);
        let mut record = vec![0i64; RECORD_BYTES / 8];
        let mut pairs = Vec::with_capacity(keys as usize);
        for k in 0..keys {
            let addr = rack.alloc(RECORD_BYTES as u64);
            for w in record.iter_mut() {
                *w = rng.next_i64();
            }
            rack.write_words(addr, &record);
            pairs.push((k as i64, addr as i64));
        }
        let tree = BPlusTree::build_sorted(rack, &pairs, 7);
        Self { tree, keys }
    }

    /// Functional range query: record addresses for `count` keys from
    /// `start`.
    pub fn scan(&self, rack: &mut Rack, start: i64, count: usize) -> Vec<GAddr> {
        self.tree
            .scan(rack, start, count)
            .into_iter()
            .map(|v| v as GAddr)
            .collect()
    }

    pub fn get(&self, rack: &mut Rack, key: i64) -> Option<GAddr> {
        self.tree.get(rack, key).map(|v| v as GAddr)
    }

    /// DES op for a YCSB-E request.
    pub fn make_op(&self, ycsb: &YcsbOp) -> Op {
        match *ycsb {
            YcsbOp::Scan(start, len) => {
                let start = (start % self.keys) as i64;
                // locate + buffered-scan continuation chain (shared
                // wiring: `BPlusTree::scan_op`); the record payloads
                // ride back on the scan stage's response
                let mut op = self.tree.scan_op(start, len);
                op.stages[1].object_read_bytes =
                    (len * RECORD_BYTES) as u32;
                op
            }
            YcsbOp::Read(k) | YcsbOp::Update(k) | YcsbOp::Insert(k) => {
                // YCSB-E inserts modeled as point lookups of the
                // insertion position (leaf split handled host-side).
                let k = (k % self.keys) as i64;
                let mut sp = [0i64; SP_WORDS];
                sp[SP_KEY as usize] = k;
                let mut st = Stage::new(
                    self.tree.get_program(),
                    self.tree.root,
                    sp,
                );
                st.object_read_bytes = RECORD_BYTES as u32;
                Op { stages: vec![st], cpu_post_ns: 0 }
            }
        }
    }

    pub fn op_stream(
        &self,
        mut workload: YcsbWorkload,
        count: u64,
    ) -> impl FnMut(u64) -> Option<Op> + '_ {
        move |i| {
            if i >= count {
                return None;
            }
            Some(self.make_op(&workload.next_op()))
        }
    }

    pub fn profile(&self) -> WorkloadProfile {
        WorkloadProfile {
            name: "WiredTiger",
            ratio: self.tree.get_program().ratio(),
            avg_iters: 25.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rack::RackConfig;
    use crate::workloads::YcsbSpec;

    fn rack() -> Rack {
        Rack::new(RackConfig {
            nodes: 2,
            node_capacity: 256 << 20,
            granularity: 4 << 20,
            ..Default::default()
        })
    }

    #[test]
    fn scan_returns_contiguous_records() {
        let mut r = rack();
        let app = WiredTigerApp::build(&mut r, 2000, 1);
        let recs = app.scan(&mut r, 500, 20);
        assert_eq!(recs.len(), 20);
        // addresses must match point lookups
        for (i, &addr) in recs.iter().enumerate() {
            assert_eq!(
                app.get(&mut r, 500 + i as i64),
                Some(addr),
                "key {}",
                500 + i as i64
            );
        }
    }

    #[test]
    fn ycsb_e_serves_through_the_rack() {
        let mut r = rack();
        let app = WiredTigerApp::build(&mut r, 5000, 2);
        let w = YcsbWorkload::new(YcsbSpec::E, 5000, true, 7)
            .with_max_scan(40);
        let mut ops = app.op_stream(w, 100);
        let report = r.serve(move |i| ops(i), 4);
        assert_eq!(report.completed, 100);
        assert_eq!(report.trapped, 0);
        // scans traverse many leaves: iterations per op >> 1
        assert!(
            report.total_iters > 400,
            "iters {}",
            report.total_iters
        );
    }

    #[test]
    fn functional_op_matches_ds_scan() {
        let mut r = rack();
        let app = WiredTigerApp::build(&mut r, 1000, 3);
        let op = app.make_op(&YcsbOp::Scan(100, 15));
        let sp = r.run_op_functional(&op);
        // after the final stage, emitted count for the last round is in
        // sp[3]; total correctness is checked via ds::scan
        assert!(sp[3] > 0);
        let recs = app.scan(&mut r, 100, 15);
        assert_eq!(recs.len(), 15);
    }
}
