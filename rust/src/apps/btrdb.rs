//! BTrDB-like time-series database (paper §6: windowed aggregation over
//! µPMU voltage/current/phase readings; 1 s – 8 s windows).
//!
//! Samples are keyed by timestamp in a B+Tree with time-ordered leaves.
//! A window query is the three-part pipeline:
//!   1. offloaded locate to the window's first leaf;
//!   2. offloaded leaf-chain *sum* aggregation (PULSE iterator);
//!   3. CPU-side finalize — mean from the fixed sample rate, min/max
//!      through the `window_agg` XLA artifact when fine-grained
//!      rendering is requested (the L1 Pallas kernel running under the
//!      Rust PJRT client — never Python).

use crate::ds::bplustree::{BPlusTree, FANOUT};
use crate::ds::{SP_ACC_SUM, SP_KEY};
use crate::isa::SP_WORDS;
use crate::rack::{Op, Rack, Stage, StartAddr};
#[cfg(feature = "xla")]
use crate::runtime::WindowAggExe;
use crate::workloads::timeseries::{PmuSample, PmuSource};

use super::WorkloadProfile;

pub struct BtrDbApp {
    pub tree: BPlusTree,
    pub samples: Vec<PmuSample>,
    pub dt_ns: i64,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WindowStats {
    pub sum_mv: i64,
    pub count: i64,
    pub mean_mv: f64,
}

impl BtrDbApp {
    /// Ingest `n` µPMU samples (time-ordered bulk load, as BTrDB does).
    pub fn build(rack: &mut Rack, n: usize, seed: u64) -> Self {
        let mut src = PmuSource::new(seed);
        let samples = src.take(n);
        let pairs: Vec<(i64, i64)> = samples
            .iter()
            .map(|s| (s.t_ns, s.voltage_mv))
            .collect();
        let tree = BPlusTree::build_sorted(rack, &pairs, FANOUT);
        Self { tree, samples, dt_ns: src.dt_ns }
    }

    /// Functional windowed aggregate over [t0, t0 + window_ns).
    pub fn window_sum(&self, rack: &mut Rack, t0: i64, window_ns: i64) -> WindowStats {
        let hi = t0 + window_ns - 1;
        let sum = self.tree.sum_range(rack, t0, hi);
        let count = self
            .samples
            .iter()
            .filter(|s| s.t_ns >= t0 && s.t_ns <= hi)
            .count() as i64;
        WindowStats {
            sum_mv: sum,
            count,
            mean_mv: if count > 0 { sum as f64 / count as f64 } else { 0.0 },
        }
    }

    /// Host-side reference.
    pub fn host_window_sum(&self, t0: i64, window_ns: i64) -> WindowStats {
        let hi = t0 + window_ns - 1;
        let mut sum = 0i64;
        let mut count = 0i64;
        for s in &self.samples {
            if s.t_ns >= t0 && s.t_ns <= hi {
                sum += s.voltage_mv;
                count += 1;
            }
        }
        WindowStats {
            sum_mv: sum,
            count,
            mean_mv: if count > 0 { sum as f64 / count as f64 } else { 0.0 },
        }
    }

    /// Fine-grained per-window (sum, mean, min, max) over a dense tile
    /// of 4096 samples starting at `start_idx`, through the AOT XLA
    /// window_agg artifact (the Mr.-Plotter-style rendering path).
    /// Requires the `xla` feature (the PJRT runtime path).
    #[cfg(feature = "xla")]
    pub fn render_tile(
        &self,
        exe: &WindowAggExe,
        start_idx: usize,
    ) -> anyhow::Result<crate::runtime::WindowAggOut> {
        let n = exe.n;
        anyhow::ensure!(
            start_idx + n <= self.samples.len(),
            "tile out of range"
        );
        let values: Vec<f32> = self.samples[start_idx..start_idx + n]
            .iter()
            .map(|s| s.voltage_mv as f32 / 1000.0)
            .collect();
        exe.run(&values)
    }

    /// DES op: locate + aggregate for one window query.
    pub fn make_op(&self, t0: i64, window_ns: i64) -> Op {
        let hi = t0 + window_ns - 1;
        let mut sp1 = [0i64; SP_WORDS];
        sp1[SP_KEY as usize] = t0;
        let s1 = Stage::new(
            self.tree.locate_program(),
            self.tree.root,
            sp1,
        );
        let mut s2 = Stage::new(
            self.tree.sum_program(),
            0,
            [0i64; SP_WORDS],
        );
        s2.start = StartAddr::FromPrevSp(crate::ds::SP_RESULT);
        s2.sp[SP_KEY as usize] = hi;
        s2.sp[SP_ACC_SUM as usize] = 0;
        Op { stages: vec![s1, s2], cpu_post_ns: 200 }
    }

    /// Window queries at a given resolution (paper: 1 s to 8 s).
    pub fn op_stream(
        &self,
        window_ns: i64,
        count: u64,
        seed: u64,
    ) -> impl FnMut(u64) -> Option<Op> + '_ {
        let mut rng = crate::util::prng::Rng::with_stream(seed, 0xB7D);
        let span = self.samples.last().map(|s| s.t_ns).unwrap_or(0);
        move |i| {
            if i >= count {
                return None;
            }
            let max_t0 = (span - window_ns).max(1);
            let t0 = rng.below(max_t0 as u64) as i64;
            Some(self.make_op(t0, window_ns))
        }
    }

    /// Iterations a window of `window_ns` takes ≈ leaves + tree depth
    /// (Table 3 reports 38–227 for 1 s – 8 s).
    pub fn profile(&self, window_ns: i64) -> WorkloadProfile {
        let samples = window_ns as f64 / self.dt_ns as f64;
        WorkloadProfile {
            name: "BTrDB",
            ratio: self.tree.sum_program().ratio(),
            avg_iters: samples / FANOUT as f64 + 4.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rack::RackConfig;

    fn rack() -> Rack {
        Rack::new(RackConfig {
            nodes: 2,
            node_capacity: 256 << 20,
            granularity: 4 << 20,
            ..Default::default()
        })
    }

    const SEC: i64 = 1_000_000_000;

    #[test]
    fn window_sum_matches_host() {
        let mut r = rack();
        let app = BtrDbApp::build(&mut r, 4000, 1);
        for (t0, w) in [(0, SEC), (3 * SEC, SEC), (5 * SEC, 2 * SEC)] {
            let got = app.window_sum(&mut r, t0, w);
            let want = app.host_window_sum(t0, w);
            assert_eq!(got, want, "window {t0}+{w}");
            assert!(want.count > 100, "window too small: {}", want.count);
        }
    }

    #[test]
    fn mean_is_near_nominal_voltage() {
        let mut r = rack();
        let app = BtrDbApp::build(&mut r, 2000, 2);
        let s = app.window_sum(&mut r, 0, 8 * SEC);
        assert!(
            (s.mean_mv - 120_000.0).abs() < 5_000.0,
            "mean {}",
            s.mean_mv
        );
    }

    #[test]
    fn des_window_queries_complete() {
        let mut r = rack();
        let app = BtrDbApp::build(&mut r, 8000, 3);
        let mut ops = app.op_stream(SEC, 50, 9);
        let report = r.serve(move |i| ops(i), 4);
        assert_eq!(report.completed, 50);
        assert_eq!(report.trapped, 0);
        // 1 s window ≈ 120 samples / 7 per leaf ≈ 17 leaves + descend
        assert!(
            report.total_iters > 50 * 15,
            "iters {}",
            report.total_iters
        );
    }

    #[test]
    fn larger_windows_take_longer() {
        let mut r = rack();
        let app = BtrDbApp::build(&mut r, 16000, 4);
        let lat_of = |r: &mut Rack, w| {
            let mut ops = app.op_stream(w, 30, 11);
            let rep = r.serve(move |i| ops(i), 1);
            rep.latency.mean()
        };
        let l1 = lat_of(&mut r, SEC);
        let l8 = lat_of(&mut r, 8 * SEC);
        assert!(l8 > 2.0 * l1, "1s {l1} vs 8s {l8}");
    }

    #[test]
    fn profile_iterations_match_table3_band() {
        let mut r = rack();
        let app = BtrDbApp::build(&mut r, 2000, 5);
        let p1 = app.profile(SEC);
        let p8 = app.profile(8 * SEC);
        assert!(p1.avg_iters > 15.0 && p1.avg_iters < 60.0);
        assert!(p8.avg_iters > 100.0 && p8.avg_iters < 300.0);
    }
}
