//! WebService application (paper §6, from AIFM [127]): requests carry a
//! user ID, resolved through an in-memory hash table to an 8 KB object,
//! which is then encrypted (ChaCha20 stream cipher, RFC 8439) and
//! compressed (LZSS) before being returned. YCSB A/B/C with Zipf or
//! uniform key choosers.
//!
//! The offline registry carries no `aes`/`flate2`, so both primitives
//! are implemented in-repo (std-only): real CPU work with the same
//! cost shape as the paper's AES-CTR + DEFLATE pipeline.
//!
//! The hash lookup is the offloaded pointer traversal; the 8 KB object
//! rides back on the response (modeled as response payload); the
//! encrypt+compress really runs on the CPU — its measured per-op cost
//! calibrates `Op::cpu_post_ns` for the DES.

use std::sync::Arc;

use super::WorkloadProfile;
use crate::ds::HashMapDs;
use crate::isa::SP_WORDS;
use crate::mem::GAddr;
use crate::rack::{Op, Rack, Stage};
use crate::sim::Ns;
use crate::util::prng::Rng;
use crate::workloads::{YcsbOp, YcsbWorkload};

pub const OBJECT_BYTES: usize = 8192;

pub struct WebServiceApp {
    pub index: HashMapDs,
    pub users: u64,
    objects: Vec<GAddr>,
    /// measured cost of encrypt+compress of one 8 KB object
    pub post_ns: Ns,
    rng: Rng,
}

impl WebServiceApp {
    /// Build the index + object store for `users` users.
    pub fn build(rack: &mut Rack, users: u64, seed: u64) -> Self {
        let mut rng = Rng::with_stream(seed, 0x3EB);
        let mut index = HashMapDs::build(rack, (users as usize).max(16));
        let mut objects = Vec::with_capacity(users as usize);
        let mut obj = vec![0i64; OBJECT_BYTES / 8];
        for uid in 0..users {
            let addr = rack.alloc(OBJECT_BYTES as u64);
            for w in obj.iter_mut() {
                *w = rng.next_i64();
            }
            rack.write_words(addr, &obj);
            index.insert(rack, uid as i64, addr as i64);
            objects.push(addr);
        }
        let post_ns = Self::calibrate_post();
        Self { index, users, objects, post_ns, rng }
    }

    /// Really run ChaCha20 + LZSS over an 8 KB buffer and measure it.
    pub fn process_object(data: &mut [u8]) -> Vec<u8> {
        let key = [0x42424242u32; 8];
        let nonce = [0u32, 0, 0x5EED];
        let mut block = [0u8; 64];
        for (bi, chunk) in data.chunks_mut(64).enumerate() {
            chacha20_block(&key, bi as u32, &nonce, &mut block);
            for (b, k) in chunk.iter_mut().zip(block.iter()) {
                *b ^= k;
            }
        }
        lzss_compress(data)
    }

    fn calibrate_post() -> Ns {
        let mut buf = vec![0xA5u8; OBJECT_BYTES];
        // warm-up
        let _ = Self::process_object(&mut buf);
        let rounds = 20;
        let t0 = std::time::Instant::now();
        for _ in 0..rounds {
            let _ = Self::process_object(&mut buf);
        }
        (t0.elapsed().as_nanos() as u64 / rounds).max(1_000)
    }

    /// Functional GET: offloaded hash lookup, then object fetch +
    /// process (really executed).
    pub fn get(&mut self, rack: &mut Rack, uid: i64) -> Option<Vec<u8>> {
        let addr = self.index.get(rack, uid)? as GAddr;
        let mut words = vec![0i64; OBJECT_BYTES / 8];
        rack.read_words(addr, &mut words);
        let mut bytes = Vec::with_capacity(OBJECT_BYTES);
        for w in &words {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        Some(Self::process_object(&mut bytes))
    }

    /// Functional UPDATE: rewrite the object, update index in place.
    pub fn update(&mut self, rack: &mut Rack, uid: i64) -> bool {
        if uid as u64 >= self.users {
            return false;
        }
        let addr = self.objects[uid as usize];
        let mut obj = vec![0i64; OBJECT_BYTES / 8];
        for w in obj.iter_mut() {
            *w = self.rng.next_i64();
        }
        rack.write_words(addr, &obj);
        self.index.update(rack, uid, addr as i64)
    }

    /// DES op for one YCSB request.
    pub fn make_op(&self, ycsb: &YcsbOp) -> Op {
        match *ycsb {
            YcsbOp::Read(uid) | YcsbOp::Scan(uid, _) => {
                let uid = (uid % self.users) as i64;
                let mut sp = [0i64; SP_WORDS];
                sp[0] = uid;
                let mut stage = Stage::new(
                    self.index.find_program(),
                    self.index.bucket_ptr(uid),
                    sp,
                );
                stage.object_read_bytes = OBJECT_BYTES as u32;
                Op { stages: vec![stage], cpu_post_ns: self.post_ns }
            }
            YcsbOp::Update(uid) | YcsbOp::Insert(uid) => {
                let uid = (uid % self.users) as i64;
                let mut sp = [0i64; SP_WORDS];
                sp[0] = uid;
                sp[1] = self.objects[uid as usize] as i64;
                let stage = Stage::new(
                    self.index.update_program(),
                    self.index.bucket_ptr(uid),
                    sp,
                );
                // update ships the new 8 KB object up front; response is
                // small. Model payload on the response path as well for
                // symmetric accounting.
                Op { stages: vec![stage], cpu_post_ns: self.post_ns }
            }
        }
    }

    /// Op stream for the DES from a YCSB workload.
    pub fn op_stream(
        &self,
        mut workload: YcsbWorkload,
        count: u64,
    ) -> impl FnMut(u64) -> Option<Op> + '_ {
        move |i| {
            if i >= count {
                return None;
            }
            Some(self.make_op(&workload.next_op()))
        }
    }

    pub fn profile(&self) -> WorkloadProfile {
        WorkloadProfile {
            name: "WebService",
            ratio: self.index.find_program().ratio(),
            avg_iters: 2.0, // sentinel + avg chain position at LF 1.0
        }
    }
}

/// `Arc` re-export convenience for op closures.
pub type SharedIter = Arc<crate::compiler::CompiledIter>;

// ---------------------------------------------------------------------
// std-only crypto/compression primitives (see module docs)
// ---------------------------------------------------------------------

#[inline]
fn quarter_round(s: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
    s[a] = s[a].wrapping_add(s[b]);
    s[d] = (s[d] ^ s[a]).rotate_left(16);
    s[c] = s[c].wrapping_add(s[d]);
    s[b] = (s[b] ^ s[c]).rotate_left(12);
    s[a] = s[a].wrapping_add(s[b]);
    s[d] = (s[d] ^ s[a]).rotate_left(8);
    s[c] = s[c].wrapping_add(s[d]);
    s[b] = (s[b] ^ s[c]).rotate_left(7);
}

/// One 64-byte ChaCha20 keystream block (RFC 8439 §2.3).
fn chacha20_block(
    key: &[u32; 8],
    counter: u32,
    nonce: &[u32; 3],
    out: &mut [u8; 64],
) {
    let mut state = [
        0x6170_7865,
        0x3320_646e,
        0x7962_2d32,
        0x6b20_6574,
        key[0],
        key[1],
        key[2],
        key[3],
        key[4],
        key[5],
        key[6],
        key[7],
        counter,
        nonce[0],
        nonce[1],
        nonce[2],
    ];
    let init = state;
    for _ in 0..10 {
        quarter_round(&mut state, 0, 4, 8, 12);
        quarter_round(&mut state, 1, 5, 9, 13);
        quarter_round(&mut state, 2, 6, 10, 14);
        quarter_round(&mut state, 3, 7, 11, 15);
        quarter_round(&mut state, 0, 5, 10, 15);
        quarter_round(&mut state, 1, 6, 11, 12);
        quarter_round(&mut state, 2, 7, 8, 13);
        quarter_round(&mut state, 3, 4, 9, 14);
    }
    for (i, w) in state.iter().enumerate() {
        let v = w.wrapping_add(init[i]);
        out[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
    }
}

const LZSS_MIN_MATCH: usize = 4;
const LZSS_MAX_MATCH: usize = 18;

#[inline]
fn lzss_hash(a: u8, b: u8, c: u8) -> usize {
    ((a as usize) << 4 ^ (b as usize) << 2 ^ (c as usize)) & 0xFFF
}

/// LZSS with a 4 KB window: 1 flag byte per 8 items; a literal byte or
/// a 2-byte (offset:12, len-3:4) back-reference. A 4-byte LE length
/// header makes the stream self-describing for `lzss_decompress`.
/// High-entropy (encrypted) data stays near input size, as the paper's
/// DEFLATE stage does.
pub fn lzss_compress(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() + data.len() / 8 + 8);
    out.extend_from_slice(&(data.len() as u32).to_le_bytes());
    let mut head = [usize::MAX; 1 << 12];
    let mut i = 0usize;
    let mut flag_pos = out.len();
    out.push(0);
    let mut flag_bit = 0u8;
    while i < data.len() {
        if flag_bit == 8 {
            flag_pos = out.len();
            out.push(0);
            flag_bit = 0;
        }
        let mut best_len = 0usize;
        let mut best_off = 0usize;
        if i + LZSS_MIN_MATCH <= data.len() {
            let h = lzss_hash(data[i], data[i + 1], data[i + 2]);
            let cand = head[h];
            if cand != usize::MAX && i - cand < 4096 {
                let max = (data.len() - i).min(LZSS_MAX_MATCH);
                let mut l = 0;
                while l < max && data[cand + l] == data[i + l] {
                    l += 1;
                }
                if l >= LZSS_MIN_MATCH {
                    best_len = l;
                    best_off = i - cand;
                }
            }
            head[h] = i;
        }
        if best_len >= LZSS_MIN_MATCH {
            out.push((best_off & 0xFF) as u8);
            out.push(
                (((best_off >> 8) as u8) << 4)
                    | ((best_len - 3) as u8),
            );
            i += best_len;
        } else {
            out[flag_pos] |= 1 << flag_bit;
            out.push(data[i]);
            i += 1;
        }
        flag_bit += 1;
    }
    out
}

/// Inverse of [`lzss_compress`].
pub fn lzss_decompress(stream: &[u8]) -> Option<Vec<u8>> {
    if stream.len() < 4 {
        return None;
    }
    let n = u32::from_le_bytes(stream[..4].try_into().ok()?) as usize;
    let mut out = Vec::with_capacity(n);
    let mut pos = 4usize;
    let mut flags = 0u8;
    let mut flag_bit = 8u8;
    while out.len() < n {
        if flag_bit == 8 {
            flags = *stream.get(pos)?;
            pos += 1;
            flag_bit = 0;
        }
        if flags >> flag_bit & 1 == 1 {
            out.push(*stream.get(pos)?);
            pos += 1;
        } else {
            let lo = *stream.get(pos)? as usize;
            let hi = *stream.get(pos + 1)? as usize;
            pos += 2;
            let off = lo | (hi >> 4) << 8;
            let len = (hi & 0x0F) + 3;
            if off == 0 || off > out.len() {
                return None;
            }
            let start = out.len() - off;
            for k in 0..len {
                let b = out[start + k];
                out.push(b);
            }
        }
        flag_bit += 1;
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rack::RackConfig;
    use crate::workloads::YcsbSpec;

    fn rack() -> Rack {
        Rack::new(RackConfig {
            nodes: 2,
            node_capacity: 256 << 20,
            granularity: 4 << 20,
            ..Default::default()
        })
    }

    #[test]
    fn build_and_get() {
        let mut r = rack();
        let mut app = WebServiceApp::build(&mut r, 100, 1);
        let out = app.get(&mut r, 42).expect("user 42");
        assert!(!out.is_empty());
        // random 8 KB compresses poorly but deterministically
        let again = app.get(&mut r, 42).unwrap();
        assert_eq!(out, again);
        assert!(app.get(&mut r, 100_000).is_none());
    }

    #[test]
    fn update_changes_object() {
        let mut r = rack();
        let mut app = WebServiceApp::build(&mut r, 10, 2);
        let before = app.get(&mut r, 3).unwrap();
        assert!(app.update(&mut r, 3));
        let after = app.get(&mut r, 3).unwrap();
        assert_ne!(before, after);
    }

    #[test]
    fn encrypt_compress_is_deterministic_and_real() {
        let mut a = vec![7u8; 4096];
        let mut b = vec![7u8; 4096];
        let ca = WebServiceApp::process_object(&mut a);
        let cb = WebServiceApp::process_object(&mut b);
        assert_eq!(ca, cb);
        // constant input encrypts to high-entropy bytes; LZSS of
        // random-looking data stays near input size
        assert!(ca.len() > 3000, "compressed to {}", ca.len());
    }

    #[test]
    fn lzss_round_trips_and_compresses_runs() {
        let mut data = vec![7u8; 2000];
        data.extend((0..200u32).map(|i| (i % 251) as u8));
        let c = lzss_compress(&data);
        assert!(c.len() < data.len() / 2, "run did not compress: {}", c.len());
        assert_eq!(lzss_decompress(&c).unwrap(), data);
        // high-entropy input round-trips too
        let mut rng = Rng::new(0xC0DE);
        let noise: Vec<u8> =
            (0..4096).map(|_| rng.next_i64() as u8).collect();
        let cn = lzss_compress(&noise);
        assert_eq!(lzss_decompress(&cn).unwrap(), noise);
    }

    #[test]
    fn calibrated_post_cost_is_sane() {
        let mut r = rack();
        let app = WebServiceApp::build(&mut r, 4, 3);
        assert!(app.post_ns >= 1_000, "{}", app.post_ns);
        assert!(app.post_ns < 10_000_000, "{}", app.post_ns);
    }

    #[test]
    fn serves_ycsb_through_the_rack() {
        let mut r = rack();
        let app = WebServiceApp::build(&mut r, 200, 4);
        let w = YcsbWorkload::new(YcsbSpec::B, 200, true, 9);
        let mut ops = app.op_stream(w, 150);
        let report = r.serve(move |i| ops(i), 8);
        assert_eq!(report.completed, 150);
        assert_eq!(report.trapped, 0);
        // 8 KB responses dominate net bytes
        assert!(report.net_bytes > 150 * 8192 / 2);
    }
}
