//! WebService application (paper §6, from AIFM [127]): requests carry a
//! user ID, resolved through an in-memory hash table to an 8 KB object,
//! which is then encrypted (AES-128-CTR) and compressed (DEFLATE) before
//! being returned. YCSB A/B/C with Zipf or uniform key choosers.
//!
//! The hash lookup is the offloaded pointer traversal; the 8 KB object
//! rides back on the response (modeled as response payload); the
//! encrypt+compress really runs on the CPU — its measured per-op cost
//! calibrates `Op::cpu_post_ns` for the DES.

use aes::cipher::{BlockEncrypt, KeyInit};
use aes::Aes128;
use flate2::write::DeflateEncoder;
use flate2::Compression;
use std::io::Write;
use std::sync::Arc;

use super::WorkloadProfile;
use crate::ds::HashMapDs;
use crate::isa::SP_WORDS;
use crate::mem::GAddr;
use crate::rack::{Op, Rack, Stage};
use crate::sim::Ns;
use crate::util::prng::Rng;
use crate::workloads::{YcsbOp, YcsbWorkload};

pub const OBJECT_BYTES: usize = 8192;

pub struct WebServiceApp {
    pub index: HashMapDs,
    pub users: u64,
    objects: Vec<GAddr>,
    /// measured cost of encrypt+compress of one 8 KB object
    pub post_ns: Ns,
    rng: Rng,
}

impl WebServiceApp {
    /// Build the index + object store for `users` users.
    pub fn build(rack: &mut Rack, users: u64, seed: u64) -> Self {
        let mut rng = Rng::with_stream(seed, 0x3EB);
        let mut index = HashMapDs::build(rack, (users as usize).max(16));
        let mut objects = Vec::with_capacity(users as usize);
        let mut obj = vec![0i64; OBJECT_BYTES / 8];
        for uid in 0..users {
            let addr = rack.alloc(OBJECT_BYTES as u64);
            for w in obj.iter_mut() {
                *w = rng.next_i64();
            }
            rack.write_words(addr, &obj);
            index.insert(rack, uid as i64, addr as i64);
            objects.push(addr);
        }
        let post_ns = Self::calibrate_post();
        Self { index, users, objects, post_ns, rng }
    }

    /// Really run AES-CTR + DEFLATE over an 8 KB buffer and measure it.
    pub fn process_object(data: &mut [u8]) -> Vec<u8> {
        // AES-128-CTR via ECB on counter blocks XORed into the payload.
        let key = [0x42u8; 16];
        let cipher = Aes128::new(&key.into());
        let mut ctr = [0u8; 16];
        for (i, chunk) in data.chunks_mut(16).enumerate() {
            ctr[0..8].copy_from_slice(&(i as u64).to_le_bytes());
            let mut block = ctr.into();
            cipher.encrypt_block(&mut block);
            for (b, k) in chunk.iter_mut().zip(block.iter()) {
                *b ^= k;
            }
        }
        let mut enc =
            DeflateEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(data).unwrap();
        enc.finish().unwrap()
    }

    fn calibrate_post() -> Ns {
        let mut buf = vec![0xA5u8; OBJECT_BYTES];
        // warm-up
        let _ = Self::process_object(&mut buf);
        let rounds = 20;
        let t0 = std::time::Instant::now();
        for _ in 0..rounds {
            let _ = Self::process_object(&mut buf);
        }
        (t0.elapsed().as_nanos() as u64 / rounds).max(1_000)
    }

    /// Functional GET: offloaded hash lookup, then object fetch +
    /// process (really executed).
    pub fn get(&mut self, rack: &mut Rack, uid: i64) -> Option<Vec<u8>> {
        let addr = self.index.get(rack, uid)? as GAddr;
        let mut words = vec![0i64; OBJECT_BYTES / 8];
        rack.read_words(addr, &mut words);
        let mut bytes = Vec::with_capacity(OBJECT_BYTES);
        for w in &words {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        Some(Self::process_object(&mut bytes))
    }

    /// Functional UPDATE: rewrite the object, update index in place.
    pub fn update(&mut self, rack: &mut Rack, uid: i64) -> bool {
        if uid as u64 >= self.users {
            return false;
        }
        let addr = self.objects[uid as usize];
        let mut obj = vec![0i64; OBJECT_BYTES / 8];
        for w in obj.iter_mut() {
            *w = self.rng.next_i64();
        }
        rack.write_words(addr, &obj);
        self.index.update(rack, uid, addr as i64)
    }

    /// DES op for one YCSB request.
    pub fn make_op(&self, ycsb: &YcsbOp) -> Op {
        match *ycsb {
            YcsbOp::Read(uid) | YcsbOp::Scan(uid, _) => {
                let uid = (uid % self.users) as i64;
                let mut sp = [0i64; SP_WORDS];
                sp[0] = uid;
                let mut stage = Stage::new(
                    self.index.find_program(),
                    self.index.bucket_ptr(uid),
                    sp,
                );
                stage.object_read_bytes = OBJECT_BYTES as u32;
                Op { stages: vec![stage], cpu_post_ns: self.post_ns }
            }
            YcsbOp::Update(uid) | YcsbOp::Insert(uid) => {
                let uid = (uid % self.users) as i64;
                let mut sp = [0i64; SP_WORDS];
                sp[0] = uid;
                sp[1] = self.objects[uid as usize] as i64;
                let stage = Stage::new(
                    self.index.update_program(),
                    self.index.bucket_ptr(uid),
                    sp,
                );
                // update ships the new 8 KB object up front; response is
                // small. Model payload on the response path as well for
                // symmetric accounting.
                Op { stages: vec![stage], cpu_post_ns: self.post_ns }
            }
        }
    }

    /// Op stream for the DES from a YCSB workload.
    pub fn op_stream(
        &self,
        mut workload: YcsbWorkload,
        count: u64,
    ) -> impl FnMut(u64) -> Option<Op> + '_ {
        move |i| {
            if i >= count {
                return None;
            }
            Some(self.make_op(&workload.next_op()))
        }
    }

    pub fn profile(&self) -> WorkloadProfile {
        WorkloadProfile {
            name: "WebService",
            ratio: self.index.find_program().ratio(),
            avg_iters: 2.0, // sentinel + avg chain position at LF 1.0
        }
    }
}

/// `Arc` re-export convenience for op closures.
pub type SharedIter = Arc<crate::compiler::CompiledIter>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rack::RackConfig;
    use crate::workloads::YcsbSpec;

    fn rack() -> Rack {
        Rack::new(RackConfig {
            nodes: 2,
            node_capacity: 256 << 20,
            granularity: 4 << 20,
            ..Default::default()
        })
    }

    #[test]
    fn build_and_get() {
        let mut r = rack();
        let mut app = WebServiceApp::build(&mut r, 100, 1);
        let out = app.get(&mut r, 42).expect("user 42");
        assert!(!out.is_empty());
        // random 8 KB compresses poorly but deterministically
        let again = app.get(&mut r, 42).unwrap();
        assert_eq!(out, again);
        assert!(app.get(&mut r, 100_000).is_none());
    }

    #[test]
    fn update_changes_object() {
        let mut r = rack();
        let mut app = WebServiceApp::build(&mut r, 10, 2);
        let before = app.get(&mut r, 3).unwrap();
        assert!(app.update(&mut r, 3));
        let after = app.get(&mut r, 3).unwrap();
        assert_ne!(before, after);
    }

    #[test]
    fn encrypt_compress_is_deterministic_and_real() {
        let mut a = vec![7u8; 4096];
        let mut b = vec![7u8; 4096];
        let ca = WebServiceApp::process_object(&mut a);
        let cb = WebServiceApp::process_object(&mut b);
        assert_eq!(ca, cb);
        // constant input encrypts to high-entropy bytes; DEFLATE of
        // random-looking data stays near input size
        assert!(ca.len() > 3000, "compressed to {}", ca.len());
    }

    #[test]
    fn calibrated_post_cost_is_sane() {
        let mut r = rack();
        let app = WebServiceApp::build(&mut r, 4, 3);
        assert!(app.post_ns >= 1_000, "{}", app.post_ns);
        assert!(app.post_ns < 10_000_000, "{}", app.post_ns);
    }

    #[test]
    fn serves_ycsb_through_the_rack() {
        let mut r = rack();
        let app = WebServiceApp::build(&mut r, 200, 4);
        let w = YcsbWorkload::new(YcsbSpec::B, 200, true, 9);
        let mut ops = app.op_stream(w, 150);
        let report = r.serve(move |i| ops(i), 8);
        assert_eq!(report.completed, 150);
        assert_eq!(report.trapped, 0);
        // 8 KB responses dominate net bytes
        assert!(report.net_bytes > 150 * 8192 / 2);
    }
}
