//! The paper's three evaluation applications (§6, Table 3):
//!
//! * `WebService` — user-ID hash index + 8 KB objects, AES-CTR encrypt +
//!   DEFLATE compress on the CPU node, driven by YCSB A/B/C;
//! * `WiredTiger` — B+Tree storage engine, YCSB-E range scans;
//! * `BTrDB` — time-series store over µPMU data with windowed
//!   sum/mean/min/max aggregation (1 s – 8 s windows).
//!
//! Each app exposes (a) functional request execution for correctness,
//! (b) an `Op` generator feeding the rack DES for the Fig. 7/8/9
//! experiments, and (c) its Table 3 workload profile (t_c/t_d ratio +
//! iterations per request).

pub mod btrdb;
pub mod webservice;
pub mod wiredtiger;

pub use btrdb::BtrDbApp;
pub use webservice::WebServiceApp;
pub use wiredtiger::WiredTigerApp;

/// Table 3-style workload profile, printed by the fig7 bench.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadProfile {
    pub name: &'static str,
    pub ratio: f64,
    pub avg_iters: f64,
}
