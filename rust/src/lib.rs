//! PULSE: distributed pointer-traversal framework for rack-scale
//! disaggregated memory — reproduction of Tang et al. (ASPLOS 2025).
//!
//! See DESIGN.md for the architecture and the hardware substitution map.

pub mod interp;
pub mod isa;
pub mod mem;
pub mod net;
pub mod sim;
pub mod util;
/// PJRT/XLA AOT runtime — requires the vendored `xla`/`anyhow` crates
/// and the `xla` cargo feature; the native interpreter is the default.
#[cfg(feature = "xla")]
pub mod runtime;

#[cfg(all(feature = "xla", not(feature = "xla-vendored")))]
compile_error!(
    "the `xla` feature needs the vendored `xla` + `anyhow` crates: \
     uncomment the dependency lines in Cargo.toml and change the \
     feature to `xla = [\"dep:xla\", \"dep:anyhow\", \"xla-vendored\"]` \
     (see rust/src/rack/README.md)"
);
pub mod testgen;
pub mod accel;
pub mod switch;
pub mod compiler;
pub mod dispatch;
pub mod rack;
pub mod obs;
pub mod backend;
pub mod live;
pub mod srv;
pub mod ds;
pub mod apps;
pub mod workloads;
pub mod baselines;
pub mod cxl;
pub mod energy;
pub mod bench_support;
