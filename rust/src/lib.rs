//! PULSE: distributed pointer-traversal framework for rack-scale
//! disaggregated memory — reproduction of Tang et al. (ASPLOS 2025).
//!
//! See DESIGN.md for the architecture and the hardware substitution map.

pub mod interp;
pub mod isa;
pub mod mem;
pub mod net;
pub mod sim;
pub mod util;
pub mod runtime;
pub mod testgen;
pub mod accel;
pub mod switch;
pub mod compiler;
pub mod dispatch;
pub mod rack;
pub mod ds;
pub mod apps;
pub mod workloads;
pub mod baselines;
pub mod cxl;
pub mod energy;
pub mod bench_support;
