//! End-to-end driver: the full PULSE system on a real (small) workload.
//!
//!     make artifacts && cargo run --release --example e2e_rack
//!
//! Proves all three layers compose:
//!   L1/L2 — the Pallas logic-step kernel + window-agg graph, AOT-lowered
//!           to HLO and executed from Rust via PJRT (no Python);
//!   L3    — the rack: dispatch engine, programmable switch, per-node
//!           accelerators, serving batched requests over three
//!           applications with latency/throughput reporting.
//!
//! Results of this run are recorded in EXPERIMENTS.md (§End-to-end).

use pulse::accel::XlaBatchEngine;
use pulse::apps::{BtrDbApp, WebServiceApp, WiredTigerApp};
use pulse::interp::{logic_pass, Workspace};
use pulse::rack::{Rack, RackConfig};
use pulse::runtime::PjrtRuntime;
use pulse::util::prng::Rng;
use pulse::workloads::{YcsbSpec, YcsbWorkload};

const SEC: i64 = 1_000_000_000;

fn main() -> anyhow::Result<()> {
    println!("=== PULSE end-to-end driver ===\n");

    // ---- Layer 1+2: AOT artifacts through PJRT ------------------------
    let rt = PjrtRuntime::new(PjrtRuntime::default_dir())?;
    let logic = rt.load_logic_step(32)?;
    let window = rt.load_window_agg(4096, 64)?;
    println!("[L1/L2] artifacts compiled on the PJRT CPU client");

    // cross-check: XLA engine vs native interpreter on a real program
    let prog = pulse::testgen::list_find_program();
    let mut rng = Rng::new(1);
    let mut ws_xla: Vec<Workspace> = (0..32)
        .map(|_| pulse::testgen::random_workspace(&mut rng))
        .collect();
    let mut ws_nat = ws_xla.clone();
    let eng = XlaBatchEngine::xla(&logic);
    let st_xla = eng.step(&prog, &mut ws_xla)?;
    let st_nat: Vec<_> = ws_nat
        .iter_mut()
        .map(|w| logic_pass(&prog, w).status)
        .collect();
    assert_eq!(st_xla, st_nat);
    assert_eq!(ws_xla, ws_nat);
    println!("[L1/L2] XLA logic engine ≡ native interpreter (32 lanes)\n");

    // ---- Layer 3: the rack serving three applications -----------------
    let mut results = Vec::new();

    // WebService: YCSB-B over 5k users, 8 KB objects really
    // encrypted+compressed for calibration.
    {
        let mut rack = Rack::new(RackConfig {
            nodes: 4,
            node_capacity: 512 << 20,
            granularity: 8 << 20,
            ..Default::default()
        });
        let app = WebServiceApp::build(&mut rack, 5_000, 7);
        println!(
            "[WebService] built 5k users ({} MB objects), post-processing \
             (AES-CTR+DEFLATE) = {:.1} µs/op",
            5_000 * 8192 / (1 << 20),
            app.post_ns as f64 / 1e3
        );
        let w = YcsbWorkload::new(YcsbSpec::B, 5_000, true, 11);
        let mut ops = app.op_stream(w, 2_000);
        let rep = rack.serve(move |i| ops(i), 32);
        println!(
            "[WebService] {} ops: p50 {:.1} µs, p99 {:.1} µs, \
             {:.0} ops/s, {} retransmits ({:.0} ms wall)",
            rep.completed,
            rep.latency.p50() as f64 / 1e3,
            rep.latency.p99() as f64 / 1e3,
            rep.tput_ops_per_s,
            rep.retransmits,
            rep.wall_ms,
        );
        results.push(("WebService/YCSB-B", rep));
    }

    // WiredTiger: YCSB-E range scans over 100k keys.
    {
        let mut rack = Rack::new(RackConfig {
            nodes: 4,
            node_capacity: 512 << 20,
            granularity: 1 << 20,
            ..Default::default()
        });
        let app = WiredTigerApp::build(&mut rack, 100_000, 5);
        let w = YcsbWorkload::new(YcsbSpec::E, 100_000, true, 13)
            .with_max_scan(100);
        let mut ops = app.op_stream(w, 1_000);
        let rep = rack.serve(move |i| ops(i), 32);
        println!(
            "[WiredTiger] {} scans: p50 {:.1} µs, p99 {:.1} µs, \
             {:.0} ops/s, {:.1} iters/op",
            rep.completed,
            rep.latency.p50() as f64 / 1e3,
            rep.latency.p99() as f64 / 1e3,
            rep.tput_ops_per_s,
            rep.total_iters as f64 / rep.completed as f64,
        );
        results.push(("WiredTiger/YCSB-E", rep));
    }

    // BTrDB: 1 s window aggregations over ~8 min of µPMU data + the
    // XLA window_agg finalize for a rendered tile.
    {
        let mut rack = Rack::new(RackConfig {
            nodes: 4,
            node_capacity: 512 << 20,
            granularity: 1 << 20,
            ..Default::default()
        });
        let app = BtrDbApp::build(&mut rack, 60_000, 3);
        let mut ops = app.op_stream(SEC, 1_000, 17);
        let rep = rack.serve(move |i| ops(i), 16);
        println!(
            "[BTrDB] {} window queries (1 s): p50 {:.1} µs, {:.0} ops/s",
            rep.completed,
            rep.latency.p50() as f64 / 1e3,
            rep.tput_ops_per_s,
        );
        // sanity: offloaded aggregation matches host reference
        let s = app.window_sum(&mut rack, 0, 4 * SEC);
        let h = app.host_window_sum(0, 4 * SEC);
        assert_eq!(s, h);
        // fine-grained rendering tile via the window_agg artifact
        let tile = app.render_tile(&window, 0)?;
        println!(
            "[BTrDB] XLA tile render: {} windows, mean V ≈ {:.1} V \
             (min {:.1}, max {:.1})",
            tile.mean.len(),
            tile.mean.iter().sum::<f32>() / tile.mean.len() as f32,
            tile.min.iter().cloned().fold(f32::INFINITY, f32::min),
            tile.max.iter().cloned().fold(f32::NEG_INFINITY, f32::max),
        );
        results.push(("BTrDB/1s-windows", rep));
    }

    println!("\n=== summary (virtual time) ===");
    println!(
        "{:<22} {:>10} {:>12} {:>12} {:>10}",
        "app", "ops", "p50 µs", "p99 µs", "kops/s"
    );
    for (name, rep) in &results {
        println!(
            "{:<22} {:>10} {:>12.1} {:>12.1} {:>10.1}",
            name,
            rep.completed,
            rep.latency.p50() as f64 / 1e3,
            rep.latency.p99() as f64 / 1e3,
            rep.tput_ops_per_s / 1e3
        );
    }
    println!("\nend-to-end OK: all layers composed.");
    Ok(())
}
