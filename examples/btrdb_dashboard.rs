//! BTrDB-style dashboard: windowed aggregation of µPMU telemetry at
//! multiple resolutions, with the PULSE-offloaded sum path and the
//! window_agg XLA artifact for fine-grained rendering (the Mr.-Plotter
//! use case the paper cites).
//!
//!     make artifacts && cargo run --release --example btrdb_dashboard

use pulse::apps::BtrDbApp;
use pulse::rack::{Rack, RackConfig};
use pulse::runtime::PjrtRuntime;

const SEC: i64 = 1_000_000_000;

fn spark(frac: f64) -> &'static str {
    const BARS: [&str; 8] =
        ["▁", "▂", "▃", "▄", "▅", "▆", "▇", "█"];
    BARS[((frac.clamp(0.0, 1.0) * 7.0).round()) as usize]
}

fn main() -> anyhow::Result<()> {
    let mut rack = Rack::new(RackConfig {
        nodes: 4,
        node_capacity: 512 << 20,
        granularity: 1 << 20,
        ..Default::default()
    });
    // ~8.3 minutes of 120 Hz voltage telemetry
    let app = BtrDbApp::build(&mut rack, 60_000, 42);
    println!(
        "ingested {} µPMU samples ({:.1} min @120 Hz)\n",
        app.samples.len(),
        app.samples.len() as f64 / 120.0 / 60.0
    );

    // multi-resolution window means via offloaded aggregation
    for win_s in [1i64, 2, 4, 8] {
        let w = win_s * SEC;
        print!("{win_s}s windows  ");
        let mut means = Vec::new();
        for k in 0..32 {
            let s = app.window_sum(&mut rack, k * w, w);
            means.push(s.mean_mv);
        }
        let lo = means.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = means.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        for m in &means {
            print!("{}", spark((m - lo) / (hi - lo + 1e-9)));
        }
        println!("  [{:.2} .. {:.2}] V", lo / 1e3, hi / 1e3);
    }

    // fine-grained tile through the AOT XLA artifact (L1 Pallas kernel
    // executing under the Rust PJRT client)
    let rt = PjrtRuntime::new(PjrtRuntime::default_dir())?;
    let exe = rt.load_window_agg(4096, 64)?;
    let tile = app.render_tile(&exe, 0)?;
    println!("\nXLA render tile (4096 samples, 64-sample windows):");
    print!("  min  ");
    let (lo, hi) = (119.0f32, 121.0f32);
    for w in 0..64 {
        print!("{}", spark(((tile.min[w] - lo) / (hi - lo)) as f64));
    }
    println!();
    print!("  max  ");
    for w in 0..64 {
        print!("{}", spark(((tile.max[w] - lo) / (hi - lo)) as f64));
    }
    println!();
    println!(
        "  mean voltage {:.2} V across the tile",
        tile.mean.iter().sum::<f32>() / 64.0
    );

    // verify against host reference
    let got = app.window_sum(&mut rack, 0, 2 * SEC);
    let want = app.host_window_sum(0, 2 * SEC);
    assert_eq!(got, want);
    println!("\noffloaded aggregation ≡ host reference ✓");
    Ok(())
}
