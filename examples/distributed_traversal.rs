//! Distributed pointer traversals (paper §5): watch a single traversal
//! hop across memory nodes via in-network re-routing, and compare
//! PULSE vs PULSE-ACC timing through the unified `TraversalBackend`
//! trait (the same interface the figure benches drive every compared
//! system through).
//!
//!     cargo run --release --example distributed_traversal

use pulse::backend::TraversalBackend;
use pulse::bench_support::make_backend;
use pulse::ds::ForwardList;
use pulse::isa::SP_WORDS;
use pulse::rack::{Op, Rack, RackConfig};

fn rack_cfg() -> RackConfig {
    RackConfig {
        nodes: 4,
        node_capacity: 64 << 20,
        granularity: 4096, // 4 KB slabs: aggressive fragmentation
        ..Default::default()
    }
}

fn build_list(rack: &mut Rack) -> ForwardList {
    let mut list = ForwardList::new();
    for i in 0..5_000 {
        list.push(rack, i);
    }
    list
}

fn main() {
    // --- functional: where does one traversal go? -----------------------
    let mut rack = Rack::new(rack_cfg());
    let list = build_list(&mut rack);
    println!("list of 5000 nodes over 4 KB slabs on 4 memory nodes\n");

    let owners: Vec<_> = {
        let mut v = Vec::new();
        let mut cur = list.head;
        for _ in 0..12 {
            let node = rack.alloc.owner(cur).unwrap();
            v.push((cur, node));
            let mut buf = [0i64; 2];
            rack.read_words(cur, &mut buf);
            cur = buf[1] as u64;
        }
        v
    };
    println!("first 12 hops of the chain:");
    for (addr, node) in owners {
        println!("  {addr:#012x} -> memory node {node}");
    }

    let before = rack.switch.stats.reroutes;
    let found = list.find(&mut rack, 4_900);
    println!(
        "\nfind(4900): {:?}, switch re-routed the request {} times \
         (no CPU involvement)",
        found.is_some(),
        rack.switch.stats.reroutes - before
    );

    // --- timed: PULSE vs PULSE-ACC (Fig. 9) through the trait -----------
    // Both systems are `TraversalBackend`s; the same pre-materialized
    // batch goes through each via the open-loop `serve_batch` path.
    let run = |kind: &str| {
        let mut backend = make_backend(kind, rack_cfg());
        let list = build_list(backend.rack_mut());
        let prog = list.find_program();
        let ops: Vec<Op> = (1..=100i64)
            .map(|n| {
                let mut sp = [0i64; SP_WORDS];
                sp[0] = 4000 + (n % 900);
                Op::new(prog.clone(), list.head, sp)
            })
            .collect();
        let report = backend.serve_batch(&ops, 4);
        (report, backend.metrics())
    };
    let (pulse, pm) = run("pulse");
    let (acc, am) = run("pulse-acc");
    println!("\nFig. 9 shape — deep traversals (≈4000 hops):");
    println!(
        "  {:<10}: mean {:.1} µs  (in-network re-routing)",
        pm.name,
        pulse.latency.mean() / 1e3
    );
    println!(
        "  {:<10}: mean {:.1} µs  ({:.2}x)",
        am.name,
        acc.latency.mean() / 1e3,
        acc.latency.mean() / pulse.latency.mean()
    );
    println!(
        "  cross-node requests: {} / {}",
        pulse.cross_node_requests, pulse.completed
    );
}
