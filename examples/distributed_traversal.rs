//! Distributed pointer traversals (paper §5): watch a single traversal
//! hop across memory nodes via in-network re-routing, and compare
//! PULSE vs PULSE-ACC (return-to-CPU) timing.
//!
//!     cargo run --release --example distributed_traversal

use pulse::ds::ForwardList;
use pulse::isa::SP_WORDS;
use pulse::rack::{Op, Rack, RackConfig};

fn build(in_network: bool) -> (Rack, ForwardList) {
    let mut rack = Rack::new(RackConfig {
        nodes: 4,
        node_capacity: 64 << 20,
        granularity: 4096, // 4 KB slabs: aggressive fragmentation
        in_network_routing: in_network,
        ..Default::default()
    });
    let mut list = ForwardList::new();
    for i in 0..5_000 {
        list.push(&mut rack, i);
    }
    (rack, list)
}

fn main() {
    // --- functional: where does one traversal go? -----------------------
    let (mut rack, list) = build(true);
    println!("list of 5000 nodes over 4 KB slabs on 4 memory nodes\n");

    let owners: Vec<_> = {
        let mut v = Vec::new();
        let mut cur = list.head;
        for _ in 0..12 {
            let node = rack.alloc.owner(cur).unwrap();
            v.push((cur, node));
            let mut buf = [0i64; 2];
            rack.read_words(cur, &mut buf);
            cur = buf[1] as u64;
        }
        v
    };
    println!("first 12 hops of the chain:");
    for (addr, node) in owners {
        println!("  {addr:#012x} -> memory node {node}");
    }

    let before = rack.switch.stats.reroutes;
    let found = list.find(&mut rack, 4_900);
    println!(
        "\nfind(4900): {:?}, switch re-routed the request {} times \
         (no CPU involvement)",
        found.is_some(),
        rack.switch.stats.reroutes - before
    );

    // --- timed: PULSE vs PULSE-ACC (Fig. 9) ------------------------------
    let run = |in_network: bool| {
        let (mut rack, list) = build(in_network);
        let prog = list.find_program();
        let head = list.head;
        let mut n = 0;
        let report = rack.serve(
            move |_| {
                n += 1;
                if n > 100 {
                    return None;
                }
                let mut sp = [0i64; SP_WORDS];
                sp[0] = 4000 + (n % 900);
                Some(Op::new(prog.clone(), head, sp))
            },
            4,
        );
        report
    };
    let pulse = run(true);
    let acc = run(false);
    println!("\nFig. 9 shape — deep traversals (≈4000 hops):");
    println!(
        "  PULSE     : mean {:.1} µs  (in-network re-routing)",
        pulse.latency.mean() / 1e3
    );
    println!(
        "  PULSE-ACC : mean {:.1} µs  ({:.2}x)",
        acc.latency.mean() / 1e3,
        acc.latency.mean() / pulse.latency.mean()
    );
    println!(
        "  cross-node requests: {} / {}",
        pulse.cross_node_requests, pulse.completed
    );
}
