//! Quickstart: build a hash table on a 4-node disaggregated rack and
//! offload lookups to the PULSE accelerators.
//!
//!     cargo run --release --example quickstart
//!
//! Walks through the paper's pipeline: iterator DSL → PULSE ISA →
//! offload decision (t_c ≤ η·t_d) → distributed execution.

use pulse::compiler::IterBuilder;
use pulse::ds::HashMapDs;
use pulse::isa::SP_WORDS;
use pulse::rack::{Rack, RackConfig};

fn main() {
    // 1. A rack: 1 CPU node + switch + 4 memory nodes, 64 MB slabs.
    let mut rack = Rack::new(RackConfig {
        nodes: 4,
        node_capacity: 256 << 20,
        granularity: 1 << 20,
        ..Default::default()
    });
    println!("rack: {} memory nodes, η = {:.2}", rack.cfg.nodes, rack.cfg.accel.eta());

    // 2. A data structure on disaggregated memory.
    let mut map = HashMapDs::build(&mut rack, 1024);
    for k in 0..100_000i64 {
        map.insert(&mut rack, k, k * k);
    }
    println!("hash table: {} entries across the rack", map.len);

    // 3. The offloaded iterator — what the DSL compiled it to.
    let find = map.find_program();
    println!(
        "\nfind() compiled to {} PULSE instructions, loads {} words/iter",
        find.program.len(),
        find.program.load_words
    );
    println!(
        "cost model: t_c = {:.0} ns, t_d = {:.0} ns, ratio = {:.2} → {}",
        find.t_c_ns,
        find.t_d_ns,
        find.ratio(),
        if find.offloadable(0.75) { "OFFLOAD" } else { "run on CPU" }
    );
    for (pc, instr) in find.program.instrs.iter().enumerate() {
        println!("  {pc:2}: {instr}");
    }

    // 4. Offloaded lookups (functional path: dispatch → switch →
    //    accelerator visits, bouncing across nodes as needed).
    println!();
    for k in [42i64, 77_777, 99_999, 123_456_789] {
        match map.get(&mut rack, k) {
            Some(v) => println!("get({k}) = {v}"),
            None => println!("get({k}) = ∅"),
        }
    }

    // 5. Where did the iterations run?
    println!("\nper-node accelerator activity:");
    for m in &rack.memnodes {
        println!(
            "  node {}: {} iterations, {} bounces, {} traps",
            m.node, m.iterations, m.bounces, m.traps
        );
    }
    println!(
        "switch: {} requests routed, {} in-network reroutes",
        rack.switch.stats.routed_requests, rack.switch.stats.reroutes
    );

    // 6. A custom iterator through the DSL: count nodes whose value
    //    exceeds a threshold along a bucket chain.
    let mut b = IterBuilder::new();
    let thresh = b.sp(0);
    let val = b.field(1);
    b.if_gt(val, thresh, |b| {
        let c = b.sp(3);
        let c2 = b.addi(c, 1);
        b.sp_store(3, c2);
    });
    let next = b.field(2);
    let zero = b.imm(0);
    b.if_eq(next, zero, |b| b.ret());
    b.advance(next);
    let counter = b.finish().expect("verify");
    let mut sp = [0i64; SP_WORDS];
    sp[0] = 1_000_000; // threshold
    let (_st, sp, iters) =
        rack.traverse(&counter, map.bucket_ptr(7), sp);
    println!(
        "\ncustom DSL iterator: {} values > 1e6 in bucket(7)'s chain \
         ({iters} iterations)",
        sp[3]
    );
}
