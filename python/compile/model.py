"""L2: the JAX compute graphs PULSE lowers to HLO artifacts.

The request path lives in Rust (L3). These functions are traced once by
``aot.py`` and shipped to ``artifacts/*.hlo.txt``; the Rust runtime
(``rust/src/runtime``) compiles each artifact with the PJRT CPU client at
startup and invokes it from the accelerator's logic-pipeline engine.

Exported graphs
---------------
``logic_batch_step``   one logic-pipeline pass over a batch of workspaces
                       (calls the L1 Pallas interpreter kernel).
``window_aggregate``   BTrDB per-window sum/min/max + mean finalize
                       (calls the L1 window_agg kernel).
"""

import jax
import jax.numpy as jnp

from .kernels import isa
from .kernels.logic_step import logic_step
from .kernels.window_agg import window_agg


def logic_batch_step(ops, imm, regs, sp, data):
    """One batched logic-pipeline step.

    Shapes: ops [MAX_INSTRS,4] i32, imm [MAX_INSTRS] i64,
    regs [B,16] i64, sp [B,32] i64, data [B,32] i64.
    Returns (regs', sp', data', status[B] i32, next_ptr[B] i64) — the
    next pointer is regs'[:, 0] (r0 == cur_ptr by convention), split out
    so the Rust scheduler can route fetches without touching the full
    register file.
    """
    regs2, sp2, data2, status = logic_step(ops, imm, regs, sp, data)
    next_ptr = regs2[:, 0]
    return regs2, sp2, data2, status, next_ptr


def window_aggregate(values, *, window):
    """Per-window (sum, mean, min, max) over a dense f32 leaf tile."""
    s, mn, mx = window_agg(values, window=window)
    mean = s / jnp.float32(window)
    return s, mean, mn, mx


def example_args_logic(batch):
    """ShapeDtypeStructs for lowering logic_batch_step at a batch size."""
    return (
        jax.ShapeDtypeStruct((isa.MAX_INSTRS, 4), jnp.int32),
        jax.ShapeDtypeStruct((isa.MAX_INSTRS,), jnp.int64),
        jax.ShapeDtypeStruct((batch, isa.NREG), jnp.int64),
        jax.ShapeDtypeStruct((batch, isa.SP_WORDS), jnp.int64),
        jax.ShapeDtypeStruct((batch, isa.DATA_WORDS), jnp.int64),
    )


def example_args_window(n, window):
    del window
    return (jax.ShapeDtypeStruct((n,), jnp.float32),)
