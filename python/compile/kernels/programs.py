"""Sample PULSE programs used by the Python tests.

These mirror the paper's ported data structures (Appendix B): the
linked-list ``std::find`` (Listing 5), the hash-bucket chain walk
(Listing 3/7), and the BST ``lower_bound`` walk (Listing 11). The Rust
compiler (``rust/src/compiler``) emits equivalent code from the iterator
DSL; here they are hand-assembled to keep the Python layer self-contained.

Memory layout convention (8 B-aligned words inside the 256 B data
window; the memory pipeline fetched ``data`` starting at ``cur_ptr``):

list node     [0]=key  [1]=value [2]=next
bst node      [0]=key  [1]=value [2]=left  [3]=right
"""

from . import isa

I = isa

# Register conventions shared with rust/src/compiler/lower.rs
R_CUR = 0       # cur_ptr (r0 by convention, paper §4.2 workspace)
R_T0 = 1        # temporaries
R_T1 = 2
R_T2 = 3
R_ZERO = 15     # holds 0 when needed

# Scratchpad word conventions
SP_KEY = 0      # search key
SP_RESULT = 1   # result value / found node pointer
SP_FLAG = 2     # KEY_NOT_FOUND marker etc.
SP_ACC = 3      # running aggregate (sum)
SP_CNT = 4      # running count

KEY_NOT_FOUND = 0x7FFFFFFFFFFFFFFF


def list_find():
    """unordered-map/list find: walk ->next until key matches or null.

    Mirrors paper Listing 3/5. Per iteration:
        key   = sp[SP_KEY]
        nkey  = data[0]; nval = data[1]; nnext = data[2]
        if nkey == key: sp[RESULT] = nval; RET
        if nnext == 0:  sp[FLAG] = KEY_NOT_FOUND; RET
        r0 = nnext; NEXT
    """
    p = [
        (I.SPL, R_T0, 0, 0, SP_KEY),        # 0: t0 = key
        (I.LDD, R_T1, 0, 0, 0),             # 1: t1 = node.key
        (I.JNE, R_T0, R_T1, 0, 6),          # 2: not equal -> 6
        (I.LDD, R_T2, 0, 0, 1),             # 3: t2 = node.value
        (I.SPS, R_T2, 0, 0, SP_RESULT),     # 4: sp[RESULT] = value
        (I.RET, 0, 0, 0, 0),                # 5: found
        (I.LDD, R_T2, 0, 0, 2),             # 6: t2 = node.next
        (I.MOVI, R_ZERO, 0, 0, 0),          # 7: zero = 0
        (I.JNE, R_T2, R_ZERO, 0, 12),       # 8: next != 0 -> 12
        (I.MOVI, R_T0, 0, 0, KEY_NOT_FOUND),  # 9: t0 = NOT_FOUND
        (I.SPS, R_T0, 0, 0, SP_FLAG),       # 10: sp[FLAG] = NOT_FOUND
        (I.RET, 0, 0, 0, 0),                # 11: not found
        (I.MOV, R_CUR, R_T2, 0, 0),         # 12: cur = next
        (I.NEXT, 0, 0, 0, 0),               # 13: next iteration
    ]
    return I.verify(p)


def bst_lower_bound():
    """std::map find / _M_lower_bound (paper Listing 11).

    sp[SP_KEY] = search key, sp[SP_RESULT] = best-so-far (y).
    Per iteration on node x (data window at cur_ptr):
        if x.key <= key is FALSE (x.key > key): x = x.left? (paper's STL
        code: key <= x.key means descend left recording y)
    We implement: if key <= x.key { y = x; x = x.left } else { x = x.right }
    Terminate with RET when x == 0 (checked at iteration start on the
    *next* pointer, since a null cur_ptr never reaches the accelerator:
    the compiler emits the null check before NEXT).
    """
    p = [
        (I.SPL, R_T0, 0, 0, SP_KEY),      # 0: t0 = key
        (I.LDD, R_T1, 0, 0, 0),           # 1: t1 = x.key
        (I.JGT, R_T0, R_T1, 0, 6),        # 2: key > x.key -> right @6
        (I.SPS, R_CUR, 0, 0, SP_RESULT),  # 3: y = x
        (I.LDD, R_T2, 0, 0, 2),           # 4: t2 = x.left
        (I.JMP, 0, 0, 0, 7),              # 5: -> null check
        (I.LDD, R_T2, 0, 0, 3),           # 6: t2 = x.right
        (I.MOVI, R_ZERO, 0, 0, 0),        # 7: zero = 0
        (I.JNE, R_T2, R_ZERO, 0, 10),     # 8: t2 != 0 -> descend @10
        (I.RET, 0, 0, 0, 0),              # 9: x == null: y is the answer
        (I.MOV, R_CUR, R_T2, 0, 0),       # 10: cur = child
        (I.NEXT, 0, 0, 0, 0),             # 11
    ]
    return I.verify(p)


def list_sum():
    """Stateful aggregation along a list: sp[ACC] += node.value,
    sp[CNT] += 1; stop at null next (BTrDB-style running aggregate)."""
    p = [
        (I.SPL, R_T0, 0, 0, SP_ACC),     # 0: t0 = acc
        (I.LDD, R_T1, 0, 0, 1),          # 1: t1 = node.value
        (I.ADD, R_T0, R_T0, R_T1, 0),    # 2: acc += value
        (I.SPS, R_T0, 0, 0, SP_ACC),     # 3
        (I.SPL, R_T0, 0, 0, SP_CNT),     # 4: t0 = cnt
        (I.MOVI, R_T1, 0, 0, 1),         # 5
        (I.ADD, R_T0, R_T0, R_T1, 0),    # 6: cnt += 1
        (I.SPS, R_T0, 0, 0, SP_CNT),     # 7
        (I.LDD, R_T2, 0, 0, 2),          # 8: t2 = node.next
        (I.MOVI, R_ZERO, 0, 0, 0),       # 9
        (I.JNE, R_T2, R_ZERO, 0, 12),    # 10: next != 0 -> 12
        (I.RET, 0, 0, 0, 0),             # 11: end of list
        (I.MOV, R_CUR, R_T2, 0, 0),      # 12
        (I.NEXT, 0, 0, 0, 0),            # 13
    ]
    return I.verify(p)


def alu_torture():
    """Straight-line ALU coverage program (no memory traffic) used by the
    kernel-vs-ref tests: exercises every ALU opcode once."""
    p = [
        (I.MOVI, 1, 0, 0, 7),             # r1 = 7
        (I.MOVI, 2, 0, 0, -3),            # r2 = -3
        (I.ADD, 3, 1, 2, 0),              # r3 = 4
        (I.SUB, 4, 1, 2, 0),              # r4 = 10
        (I.MUL, 5, 1, 2, 0),              # r5 = -21
        (I.DIV, 6, 5, 1, 0),              # r6 = -3
        (I.AND, 7, 1, 4, 0),              # r7 = 7 & 10 = 2
        (I.OR, 8, 1, 4, 0),               # r8 = 15
        (I.XOR, 9, 1, 4, 0),              # r9 = 13
        (I.NOT, 10, 1, 0, 0),             # r10 = ~7 = -8
        (I.SHL, 11, 1, 0, 4),             # r11 = 112
        (I.SHR, 12, 2, 0, 60),            # r12 = (u64)(-3) >> 60 = 15
        (I.ADDI, 13, 1, 0, 100),          # r13 = 107
        (I.MOV, 14, 13, 0, 0),            # r14 = 107
        (I.SPS, 3, 0, 0, 0),
        (I.SPS, 4, 0, 0, 1),
        (I.SPS, 5, 0, 0, 2),
        (I.SPS, 6, 0, 0, 3),
        (I.SPS, 7, 0, 0, 4),
        (I.SPS, 8, 0, 0, 5),
        (I.SPS, 9, 0, 0, 6),
        (I.SPS, 10, 0, 0, 7),
        (I.SPS, 11, 0, 0, 8),
        (I.SPS, 12, 0, 0, 9),
        (I.SPS, 13, 0, 0, 10),
        (I.SPS, 14, 0, 0, 11),
        (I.RET, 0, 0, 0, 0),
    ]
    return I.verify(p)


ALL = {
    "list_find": list_find,
    "bst_lower_bound": bst_lower_bound,
    "list_sum": list_sum,
    "alu_torture": alu_torture,
}
