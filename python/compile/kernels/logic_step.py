"""Pallas kernel: batched PULSE logic-pipeline step.

One SIMD lane per accelerator *workspace* (paper §4.2): the lane carries
``regs[16]``, ``scratch_pad[32]`` and the 256 B ``data`` window fetched by
the memory pipeline. The kernel executes one full iterator *iteration* of
the (verified) PULSE program in lock-step across the batch and reports a
terminal status per lane (NEXT_ITER / RETURN / TRAP).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's logic
pipeline is FPGA RTL. On a TPU-style target the same insight — a
restricted ISA with *forward-only* jumps, hence execution length ≤ program
length — maps to a vectorized lock-step interpreter: per-lane ``pc`` is a
vector, opcode dispatch is a select tree (no divergence), and the
workspace tile for a block of lanes lives in VMEM
(B_blk × (16+32+32) × 8 B ≈ 20 KB at B_blk = 32). No MXU use: the kernel
is VPU-bound by construction, mirroring Property 2 (t_c ≤ η·t_d).

The kernel must be lowered with ``interpret=True`` (CPU PJRT cannot run
Mosaic custom-calls); numerics are identical either way.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import isa

I64 = jnp.int64
I32 = jnp.int32


def _onehot_write(buf, idx, val, enable):
    """buf[b, idx[b]] = val[b] where enable[b], via one-hot select.

    buf: [B, W] i64, idx: [B] i32/i64, val: [B] i64, enable: [B] bool.
    Scatter-free (TPU/VPU friendly) because W is a small constant.
    """
    w = buf.shape[1]
    hot = (jnp.arange(w, dtype=I32)[None, :] == idx.astype(I32)[:, None])
    hot = hot & enable[:, None]
    return jnp.where(hot, val[:, None], buf)


def _gather_lane(buf, idx):
    """val[b] = buf[b, idx[b]] with idx clipped (validity checked by
    caller)."""
    w = buf.shape[1]
    safe = jnp.clip(idx, 0, w - 1).astype(I32)
    return jnp.take_along_axis(buf, safe[:, None], axis=1)[:, 0]


def logic_step_kernel(ops_ref, imm_ref, regs_ref, sp_ref, data_ref,
                      regs_out, sp_out, data_out, status_out):
    """Pallas kernel body. Block = whole batch tile.

    ops: [MAX_INSTRS, 4] i32 — (op, a, b, c) per slot (TRAP-padded).
    imm: [MAX_INSTRS] i64.
    regs/sp/data: [B, 16/32/32] i64. status: [B] i32.
    """
    ops = ops_ref[...]
    imm = imm_ref[...]
    regs0 = regs_ref[...]
    sp0 = sp_ref[...]
    data0 = data_ref[...]
    bsz = regs0.shape[0]

    pc0 = jnp.zeros((bsz,), I32)
    st0 = jnp.full((bsz,), isa.ST_RUNNING, I32)

    def step(_, carry):
        pc, st, regs, sp, data = carry
        live = st == isa.ST_RUNNING

        # Fetch (runaway pc is clipped; the MAX_INSTRS-1 slot is TRAP for
        # any verified program shorter than the container, and verified
        # programs end in a terminal anyway).
        safe_pc = jnp.clip(pc, 0, isa.MAX_INSTRS - 1)
        field = jnp.take(ops, safe_pc, axis=0)          # [B, 4]
        op, a, b, c = field[:, 0], field[:, 1], field[:, 2], field[:, 3]
        im = jnp.take(imm, safe_pc, axis=0)             # [B] i64

        ra = _gather_lane(regs, a)
        rb = _gather_lane(regs, b)
        rc = _gather_lane(regs, c)

        # ---- dynamic window indices -------------------------------------
        dyn_idx = rb + im                                # LDX/STX/SPLX/SPSX
        data_oob = (dyn_idx < 0) | (dyn_idx >= isa.DATA_WORDS)
        sp_oob = (dyn_idx < 0) | (dyn_idx >= isa.SP_WORDS)

        # ---- loads -------------------------------------------------------
        ld_static = _gather_lane(data, im)               # LDD
        ld_dyn = _gather_lane(data, dyn_idx)             # LDX
        sp_static = _gather_lane(sp, im)                 # SPL
        sp_dyn = _gather_lane(sp, dyn_idx)               # SPLX

        # ---- ALU ----------------------------------------------------------
        shamt = (im & 63).astype(I32)
        div_zero = rc == 0
        safe_rc = jnp.where(div_zero, jnp.int64(1), rc)
        # C-style truncated division; i64::MIN / -1 wraps to i64::MIN,
        # which is exactly what negation does in two's complement.
        q = jax.lax.div(rb, jnp.where(safe_rc == -1, jnp.int64(1), safe_rc))
        q = jnp.where(safe_rc == -1, -rb, q)

        alu = [
            (isa.MOV, rb),
            (isa.MOVI, im),
            (isa.ADD, rb + rc),
            (isa.SUB, rb - rc),
            (isa.MUL, rb * rc),
            (isa.DIV, q),
            (isa.AND, rb & rc),
            (isa.OR, rb | rc),
            (isa.XOR, rb ^ rc),
            (isa.NOT, ~rb),
            (isa.SHL, rb << shamt.astype(I64)),
            (isa.SHR, jax.lax.shift_right_logical(rb, shamt.astype(I64))),
            (isa.ADDI, rb + im),
            (isa.LDD, ld_static),
            (isa.LDX, ld_dyn),
            (isa.SPL, sp_static),
            (isa.SPLX, sp_dyn),
        ]
        reg_val = jnp.zeros((bsz,), I64)
        reg_write = jnp.zeros((bsz,), bool)
        for code, val in alu:
            hit = op == code
            reg_val = jnp.where(hit, val, reg_val)
            reg_write = reg_write | hit

        # ---- traps ---------------------------------------------------------
        trap = (
            ((op == isa.LDX) | (op == isa.STX)) & data_oob
            | ((op == isa.SPLX) | (op == isa.SPSX)) & sp_oob
            | (op == isa.DIV) & div_zero
            | (op == isa.TRAP)
            | (pc >= isa.MAX_INSTRS)
        )
        trap = trap & live

        # ---- register writeback --------------------------------------------
        do_write = reg_write & live & ~trap
        regs = _onehot_write(regs, a, reg_val, do_write)

        # ---- stores ----------------------------------------------------------
        data = _onehot_write(
            data, im.astype(I32), ra, (op == isa.STD) & live & ~trap)
        data = _onehot_write(
            data, dyn_idx.astype(I32), ra, (op == isa.STX) & live & ~trap)
        sp = _onehot_write(
            sp, im.astype(I32), ra, (op == isa.SPS) & live & ~trap)
        sp = _onehot_write(
            sp, dyn_idx.astype(I32), ra, (op == isa.SPSX) & live & ~trap)

        # ---- branches / pc --------------------------------------------------
        taken = (
            ((op == isa.JEQ) & (ra == rb))
            | ((op == isa.JNE) & (ra != rb))
            | ((op == isa.JLT) & (ra < rb))
            | ((op == isa.JLE) & (ra <= rb))
            | ((op == isa.JGT) & (ra > rb))
            | ((op == isa.JGE) & (ra >= rb))
            | (op == isa.JMP)
        )
        pc_next = jnp.where(taken, im.astype(I32), pc + 1)

        # ---- terminals -------------------------------------------------------
        st = jnp.where(trap, isa.ST_TRAP, st)
        st = jnp.where(
            live & ~trap & (op == isa.NEXT), isa.ST_NEXT_ITER, st)
        st = jnp.where(live & ~trap & (op == isa.RET), isa.ST_RETURN, st)

        pc = jnp.where(live, pc_next, pc)
        return pc, st, regs, sp, data

    # Forward-only jumps => at most MAX_INSTRS dynamic steps.
    _, st, regs, sp, data = jax.lax.fori_loop(
        0, isa.MAX_INSTRS, step, (pc0, st0, regs0, sp0, data0))

    # Lanes that never reached a terminal (impossible for verified
    # programs, possible for adversarial input) report TRAP.
    st = jnp.where(st == isa.ST_RUNNING, isa.ST_TRAP, st)

    regs_out[...] = regs
    sp_out[...] = sp
    data_out[...] = data
    status_out[...] = st


@functools.partial(jax.jit, static_argnames=("batch",))
def logic_step(ops, imm, regs, sp, data, *, batch=None):
    """Batched logic-pipeline step: pallas_call wrapper.

    Args:
        ops: [MAX_INSTRS, 4] i32; imm: [MAX_INSTRS] i64.
        regs: [B, NREG] i64; sp: [B, SP_WORDS] i64; data: [B, DATA_WORDS]
        i64.

    Returns:
        (regs', sp', data', status) — status [B] i32.
    """
    bsz = batch if batch is not None else regs.shape[0]
    out_shape = (
        jax.ShapeDtypeStruct((bsz, isa.NREG), I64),
        jax.ShapeDtypeStruct((bsz, isa.SP_WORDS), I64),
        jax.ShapeDtypeStruct((bsz, isa.DATA_WORDS), I64),
        jax.ShapeDtypeStruct((bsz,), I32),
    )
    return pl.pallas_call(
        logic_step_kernel,
        out_shape=out_shape,
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(ops, imm, regs, sp, data)
