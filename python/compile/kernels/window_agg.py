"""Pallas kernel: BTrDB stateful window aggregation.

BTrDB (paper §6, Table 3) runs stateful aggregations — sum / average /
min / max — over fixed-resolution time windows of µPMU readings. On the
real system the aggregation happens inside the iterator's scratch_pad as
the B+Tree leaves are traversed; the CPU-node frontend then renders the
per-window statistics. This kernel is the batched "finalize" stage used
by the BTrDB app and benches: given a dense tile of leaf values it
produces per-window (sum, min, max); mean is sum / count at L2.

Layout: values [N] f32 with N = n_windows * window; grid over window
blocks so each program instance reduces WINDOW values for BLOCK_WINDOWS
windows — a [BLOCK_WINDOWS, WINDOW] f32 VMEM tile (64 × 64 × 4 B = 16 KB
by default).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32

DEFAULT_BLOCK_WINDOWS = 64


def window_agg_kernel(vals_ref, sum_ref, min_ref, max_ref):
    """One grid step: reduce a [BLOCK_WINDOWS, WINDOW] tile."""
    v = vals_ref[...]
    sum_ref[...] = jnp.sum(v, axis=1, dtype=F32)
    min_ref[...] = jnp.min(v, axis=1)
    max_ref[...] = jnp.max(v, axis=1)


@functools.partial(jax.jit, static_argnames=("window", "block_windows"))
def window_agg(values, *, window, block_windows=DEFAULT_BLOCK_WINDOWS):
    """Aggregate ``values`` ([N] f32, N % window == 0) into per-window
    (sum, min, max), each [N // window] f32."""
    n = values.shape[0]
    assert n % window == 0, "N must be a multiple of window"
    n_windows = n // window
    bw = min(block_windows, n_windows)
    assert n_windows % bw == 0, "n_windows must be a multiple of the block"
    tiles = values.reshape(n_windows, window)

    grid = (n_windows // bw,)
    out_shape = (
        jax.ShapeDtypeStruct((n_windows,), F32),
        jax.ShapeDtypeStruct((n_windows,), F32),
        jax.ShapeDtypeStruct((n_windows,), F32),
    )
    return pl.pallas_call(
        window_agg_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bw, window), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((bw,), lambda i: (i,)),
            pl.BlockSpec((bw,), lambda i: (i,)),
            pl.BlockSpec((bw,), lambda i: (i,)),
        ),
        out_shape=out_shape,
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(tiles)
