"""Pure-Python/numpy oracles for the PULSE kernels.

``ref_logic_step`` executes one iterator *iteration* (one logic-pipeline
pass, paper §4.2) per batch lane with exact Python-integer arithmetic
(explicitly reduced mod 2**64), making it the trusted reference for both
the Pallas kernel (pytest, this tree) and the Rust native interpreter
(cross-checked through the AOT artifact from ``cargo test``).

``ref_window_agg`` is the jnp-free oracle for the BTrDB window-aggregation
kernel.
"""

import numpy as np

from . import isa

_MASK = (1 << 64) - 1
_SIGN = 1 << 63


def _wrap(v):
    """Reduce a Python int to signed-64 two's complement."""
    v &= _MASK
    return v - (1 << 64) if v & _SIGN else v


def _sdiv(a, b):
    """C-style truncated signed division (matches Rust wrapping_div)."""
    if b == 0:
        raise ZeroDivisionError
    q = abs(a) // abs(b)
    if (a < 0) != (b < 0):
        q = -q
    return _wrap(q)


def ref_logic_step_lane(program, regs, sp, data):
    """Execute one iteration for a single lane.

    Args:
        program: list of (op, a, b, c, imm) tuples (verified).
        regs, sp, data: lists of Python ints (signed-64 range).

    Returns:
        (regs, sp, data, status) with status in {NEXT_ITER, RETURN, TRAP}.
    """
    regs = [int(v) for v in regs]
    sp = [int(v) for v in sp]
    data = [int(v) for v in data]
    n = len(program)
    pc = 0
    steps = 0
    I = isa
    while True:
        steps += 1
        if steps > isa.MAX_INSTRS + 1:
            # Unreachable for verified programs (forward jumps only).
            return regs, sp, data, I.ST_TRAP
        if pc >= n:
            return regs, sp, data, I.ST_TRAP
        op, a, b, c, imm = program[pc]
        imm = _wrap(imm)
        nxt = pc + 1
        if op == I.NOP:
            pass
        elif op == I.LDD:
            regs[a] = data[imm]
        elif op == I.LDX:
            idx = _wrap(regs[b] + imm)
            if not 0 <= idx < isa.DATA_WORDS:
                return regs, sp, data, I.ST_TRAP
            regs[a] = data[idx]
        elif op == I.STD:
            data[imm] = regs[a]
        elif op == I.STX:
            idx = _wrap(regs[b] + imm)
            if not 0 <= idx < isa.DATA_WORDS:
                return regs, sp, data, I.ST_TRAP
            data[idx] = regs[a]
        elif op == I.SPL:
            regs[a] = sp[imm]
        elif op == I.SPLX:
            idx = _wrap(regs[b] + imm)
            if not 0 <= idx < isa.SP_WORDS:
                return regs, sp, data, I.ST_TRAP
            regs[a] = sp[idx]
        elif op == I.SPS:
            sp[imm] = regs[a]
        elif op == I.SPSX:
            idx = _wrap(regs[b] + imm)
            if not 0 <= idx < isa.SP_WORDS:
                return regs, sp, data, I.ST_TRAP
            sp[idx] = regs[a]
        elif op == I.MOV:
            regs[a] = regs[b]
        elif op == I.MOVI:
            regs[a] = imm
        elif op == I.ADD:
            regs[a] = _wrap(regs[b] + regs[c])
        elif op == I.SUB:
            regs[a] = _wrap(regs[b] - regs[c])
        elif op == I.MUL:
            regs[a] = _wrap(regs[b] * regs[c])
        elif op == I.DIV:
            if regs[c] == 0:
                return regs, sp, data, I.ST_TRAP
            regs[a] = _sdiv(regs[b], regs[c])
        elif op == I.AND:
            regs[a] = _wrap(regs[b] & regs[c])
        elif op == I.OR:
            regs[a] = _wrap(regs[b] | regs[c])
        elif op == I.XOR:
            regs[a] = _wrap(regs[b] ^ regs[c])
        elif op == I.NOT:
            regs[a] = _wrap(~regs[b])
        elif op == I.SHL:
            regs[a] = _wrap(regs[b] << (imm & 63))
        elif op == I.SHR:
            regs[a] = _wrap((regs[b] & _MASK) >> (imm & 63))
        elif op == I.ADDI:
            regs[a] = _wrap(regs[b] + imm)
        elif op in (I.JEQ, I.JNE, I.JLT, I.JLE, I.JGT, I.JGE):
            x, y = regs[a], regs[b]
            taken = {
                I.JEQ: x == y, I.JNE: x != y, I.JLT: x < y,
                I.JLE: x <= y, I.JGT: x > y, I.JGE: x >= y,
            }[op]
            if taken:
                nxt = imm
        elif op == I.JMP:
            nxt = imm
        elif op == I.NEXT:
            return regs, sp, data, I.ST_NEXT_ITER
        elif op == I.RET:
            return regs, sp, data, I.ST_RETURN
        elif op == I.TRAP:
            return regs, sp, data, I.ST_TRAP
        else:
            return regs, sp, data, I.ST_TRAP
        pc = nxt


def ref_logic_step(program, regs, sp, data):
    """Batched oracle: numpy arrays in, numpy arrays out.

    regs: [B, NREG] int64; sp: [B, SP_WORDS] int64; data: [B, DATA_WORDS]
    int64. Returns (regs, sp, data, status[B] int32).
    """
    regs = np.asarray(regs, dtype=np.int64)
    sp = np.asarray(sp, dtype=np.int64)
    data = np.asarray(data, dtype=np.int64)
    bsz = regs.shape[0]
    out_r = np.empty_like(regs)
    out_s = np.empty_like(sp)
    out_d = np.empty_like(data)
    out_st = np.empty((bsz,), dtype=np.int32)
    for i in range(bsz):
        r, s, d, st = ref_logic_step_lane(
            program, regs[i].tolist(), sp[i].tolist(), data[i].tolist()
        )
        out_r[i] = np.array([_wrap(v) for v in r], dtype=np.int64)
        out_s[i] = np.array([_wrap(v) for v in s], dtype=np.int64)
        out_d[i] = np.array([_wrap(v) for v in d], dtype=np.int64)
        out_st[i] = st
    return out_r, out_s, out_d, out_st


def ref_window_agg(values, window):
    """Oracle for the BTrDB window-aggregation kernel.

    values: [N] float32 with N % window == 0. Returns (sum, min, max),
    each [N // window] float32.
    """
    values = np.asarray(values, dtype=np.float32)
    n = values.shape[0]
    assert n % window == 0, "N must be a multiple of the window size"
    v = values.reshape(n // window, window)
    return (
        v.sum(axis=1, dtype=np.float32),
        v.min(axis=1),
        v.max(axis=1),
    )
