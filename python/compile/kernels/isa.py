"""PULSE ISA definition (Python mirror).

This module is the single Python-side source of truth for the PULSE
instruction set (paper §4.1, Table 2). The Rust coordinator has an
identical definition in ``rust/src/isa/op.rs``; the two are cross-checked
by the integration tests (random verified programs executed by the native
Rust interpreter and by the AOT-compiled XLA artifact must produce
bit-identical workspaces).

Semantics summary
-----------------
* 16 general-purpose i64 registers; ``r0`` is ``cur_ptr`` by convention.
* A 32-word (256 B) ``data`` window: the single aggregated LOAD the memory
  pipeline performs at the start of each iteration (paper §4.1).
* A 32-word (256 B) ``scratch_pad`` window: the iterator's persistent
  state / continuation (paper §3).
* Arithmetic is two's-complement wrapping i64. ``DIV`` is C-style
  truncated signed division; divisor 0 traps, ``i64::MIN / -1`` wraps.
* Only *forward* jumps are legal (paper §4.1, eBPF-style), so any verified
  program executes at most ``n_instrs`` steps — this is what makes the
  batched lock-step interpreter exact.
* Terminals: ``NEXT`` ends the iteration (next ``cur_ptr`` must be in
  ``r0``), ``RET`` ends the traversal and yields the scratch_pad, ``TRAP``
  aborts (protection/translation-failure analogue).
"""

NREG = 16
SP_WORDS = 32  # 256 B scratchpad, 8 B words
DATA_WORDS = 32  # 256 B aggregated load window
MAX_INSTRS = 64

# --- opcodes -------------------------------------------------------------
NOP = 0
LDD = 1    # r[a] = data[imm]           (static word offset)
LDX = 2    # r[a] = data[r[b] + imm]    (dynamic; OOB -> TRAP)
STD = 3    # data[imm] = r[a]
STX = 4    # data[r[b] + imm] = r[a]    (dynamic; OOB -> TRAP)
SPL = 5    # r[a] = sp[imm]
SPLX = 6   # r[a] = sp[r[b] + imm]      (dynamic; OOB -> TRAP)
SPS = 7    # sp[imm] = r[a]
SPSX = 8   # sp[r[b] + imm] = r[a]      (dynamic; OOB -> TRAP)
MOV = 9    # r[a] = r[b]
MOVI = 10  # r[a] = imm
ADD = 11   # r[a] = r[b] + r[c]
SUB = 12
MUL = 13
DIV = 14   # divisor 0 -> TRAP
AND = 15
OR = 16
XOR = 17
NOT = 18   # r[a] = ~r[b]
SHL = 19   # r[a] = r[b] << (imm & 63)
SHR = 20   # r[a] = (u64)r[b] >> (imm & 63)
ADDI = 21  # r[a] = r[b] + imm
JEQ = 22   # if r[a] == r[b]: pc = imm  (imm > pc)
JNE = 23
JLT = 24   # signed
JLE = 25
JGT = 26
JGE = 27
JMP = 28   # pc = imm (forward)
NEXT = 29  # end of iteration; r0 holds next cur_ptr
RET = 30   # end of traversal; scratch_pad is the result
TRAP = 31  # explicit failure

N_OPCODES = 32

# --- status codes (one per workspace lane) -------------------------------
ST_RUNNING = 0
ST_NEXT_ITER = 1
ST_RETURN = 2
ST_TRAP = 3

_JUMPS = (JEQ, JNE, JLT, JLE, JGT, JGE, JMP)
_TERMINALS = (NEXT, RET, TRAP)

OP_NAMES = {
    NOP: "NOP", LDD: "LDD", LDX: "LDX", STD: "STD", STX: "STX",
    SPL: "SPL", SPLX: "SPLX", SPS: "SPS", SPSX: "SPSX", MOV: "MOV",
    MOVI: "MOVI", ADD: "ADD", SUB: "SUB", MUL: "MUL", DIV: "DIV",
    AND: "AND", OR: "OR", XOR: "XOR", NOT: "NOT", SHL: "SHL",
    SHR: "SHR", ADDI: "ADDI", JEQ: "JEQ", JNE: "JNE", JLT: "JLT",
    JLE: "JLE", JGT: "JGT", JGE: "JGE", JMP: "JMP", NEXT: "NEXT",
    RET: "RET", TRAP: "TRAP",
}


def verify(program):
    """Mirror of the Rust verifier (``rust/src/isa/verify.rs``).

    ``program`` is a list of ``(op, a, b, c, imm)`` tuples. Raises
    ``ValueError`` on the first violation. Returns the program unchanged
    on success so it can be used inline.
    """
    n = len(program)
    if n == 0:
        raise ValueError("empty program")
    if n > MAX_INSTRS:
        raise ValueError(f"program too long: {n} > {MAX_INSTRS}")
    for pc, (op, a, b, c, imm) in enumerate(program):
        if not (0 <= op < N_OPCODES):
            raise ValueError(f"pc={pc}: bad opcode {op}")
        for r, used in ((a, _uses_a(op)), (b, _uses_b(op)), (c, _uses_c(op))):
            if used and not (0 <= r < NREG):
                raise ValueError(f"pc={pc}: register {r} out of range")
        if op in (LDD, STD) and not (0 <= imm < DATA_WORDS):
            raise ValueError(f"pc={pc}: data offset {imm} out of window")
        if op in (SPL, SPS) and not (0 <= imm < SP_WORDS):
            raise ValueError(f"pc={pc}: sp offset {imm} out of window")
        if op in _JUMPS:
            if not (pc < imm <= n):
                raise ValueError(
                    f"pc={pc}: jump target {imm} not strictly forward"
                )
    # Every straight-line fall-through must hit a terminal before the end.
    last_op = program[-1][0]
    if last_op not in _TERMINALS:
        raise ValueError("program does not end in NEXT/RET/TRAP")
    return program


def _uses_a(op):
    return op not in (NOP, JMP, NEXT, RET, TRAP)


def _uses_b(op):
    return op in (LDX, STX, SPLX, SPSX, MOV, ADD, SUB, MUL, DIV, AND, OR,
                  XOR, NOT, SHL, SHR, ADDI, JEQ, JNE, JLT, JLE, JGT, JGE)


def _uses_c(op):
    return op in (ADD, SUB, MUL, DIV, AND, OR, XOR)


def pack_program(program, max_instrs=MAX_INSTRS):
    """Pack a verified program into the dense array form consumed by the
    kernels: ``ops[max_instrs, 4] int32`` (op, a, b, c) and
    ``imm[max_instrs] int64``. Slots past the end are TRAP so a runaway
    pc is caught rather than silently NOP-ing.
    """
    import numpy as np

    ops = np.zeros((max_instrs, 4), dtype=np.int32)
    imm = np.zeros((max_instrs,), dtype=np.int64)
    ops[:, 0] = TRAP
    for i, (op, a, b, c, im) in enumerate(program):
        ops[i] = (op, a, b, c)
        imm[i] = np.int64(np.uint64(im & 0xFFFFFFFFFFFFFFFF))
    return ops, imm
