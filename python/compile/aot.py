"""AOT: lower the L2 graphs to HLO *text* artifacts for the Rust runtime.

HLO text (NOT ``lowered.compile()`` serialization) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly.

Run once at build time (``make artifacts``): Python never executes on the
request path.
"""

import argparse
import json
import os

import jax

# The PULSE ISA is 64-bit; everything in the logic kernel is i64.
jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels import isa  # noqa: E402

# Batch sizes the accelerator engine may use. 32 matches one workspace
# block; 256 amortizes PJRT dispatch for throughput runs.
LOGIC_BATCHES = (32, 256)
# (N, window) shapes for the BTrDB finalize kernel. 4096x64 covers the
# paper's 1 s..8 s windows at 120 Hz µPMU rate after leaf packing.
WINDOW_SHAPES = ((4096, 64), (4096, 8))


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_logic(batch: int) -> str:
    lowered = jax.jit(model.logic_batch_step).lower(
        *model.example_args_logic(batch))
    return to_hlo_text(lowered)


def lower_window(n: int, window: int) -> str:
    fn = lambda v: model.window_aggregate(v, window=window)  # noqa: E731
    lowered = jax.jit(fn).lower(*model.example_args_window(n, window))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "isa": {
            "nreg": isa.NREG,
            "sp_words": isa.SP_WORDS,
            "data_words": isa.DATA_WORDS,
            "max_instrs": isa.MAX_INSTRS,
        },
        "artifacts": {},
    }

    for batch in LOGIC_BATCHES:
        name = "logic_step.hlo.txt" if batch == 32 else (
            f"logic_step_b{batch}.hlo.txt")
        text = lower_logic(batch)
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "kind": "logic_step", "batch": batch}
        print(f"wrote {path} ({len(text)} chars)")

    for n, window in WINDOW_SHAPES:
        name = ("window_agg.hlo.txt" if (n, window) == (4096, 64)
                else f"window_agg_n{n}_w{window}.hlo.txt")
        text = lower_window(n, window)
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "kind": "window_agg", "n": n, "window": window}
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("wrote manifest.json")


if __name__ == "__main__":
    main()
