"""Pallas logic_step kernel vs pure-Python oracle: directed tests."""

import numpy as np
import pytest

from compile.kernels import isa, programs
from compile.kernels.logic_step import logic_step
from compile.kernels.ref import ref_logic_step, ref_logic_step_lane

I = isa


def run_both(prog, regs, sp, data):
    ops, imm = isa.pack_program(prog)
    kr, ks, kd, kst = logic_step(ops, imm, regs, sp, data)
    rr, rs, rd, rst = ref_logic_step(prog, regs, sp, data)
    np.testing.assert_array_equal(np.asarray(kst), rst)
    np.testing.assert_array_equal(np.asarray(kr), rr)
    np.testing.assert_array_equal(np.asarray(ks), rs)
    np.testing.assert_array_equal(np.asarray(kd), rd)
    return rr, rs, rd, rst


def blank(b=1):
    return (
        np.zeros((b, isa.NREG), dtype=np.int64),
        np.zeros((b, isa.SP_WORDS), dtype=np.int64),
        np.zeros((b, isa.DATA_WORDS), dtype=np.int64),
    )


class TestALU:
    def test_alu_torture_matches(self):
        regs, sp, data = blank(3)
        _, rs, _, rst = run_both(programs.alu_torture(), regs, sp, data)
        assert (rst == I.ST_RETURN).all()
        expect = [4, 10, -21, -3, 2, 15, 13, -8, 112, 15, 107, 107]
        assert rs[0, :12].tolist() == expect

    @pytest.mark.parametrize("x,y,op,expect", [
        (7, -3, I.ADD, 4),
        (7, -3, I.SUB, 10),
        (7, -3, I.MUL, -21),
        (-21, 7, I.DIV, -3),
        (22, 7, I.DIV, 3),      # truncation toward zero
        (-22, 7, I.DIV, -4 + 1),  # -22/7 = -3 (trunc), not -4 (floor)
        (22, -7, I.DIV, -3),
        (0x0F, 0x05, I.AND, 0x05),
        (0x0F, 0x10, I.OR, 0x1F),
        (0x0F, 0x05, I.XOR, 0x0A),
    ])
    def test_binop(self, x, y, op, expect):
        prog = I.verify([
            (I.MOVI, 1, 0, 0, x),
            (I.MOVI, 2, 0, 0, y),
            (op, 3, 1, 2, 0),
            (I.SPS, 3, 0, 0, 0),
            (I.RET, 0, 0, 0, 0),
        ])
        regs, sp, data = blank()
        _, rs, _, rst = run_both(prog, regs, sp, data)
        assert rst[0] == I.ST_RETURN
        assert rs[0, 0] == expect

    def test_wrapping_add_overflow(self):
        prog = I.verify([
            (I.MOVI, 1, 0, 0, 2**63 - 1),
            (I.MOVI, 2, 0, 0, 1),
            (I.ADD, 3, 1, 2, 0),
            (I.SPS, 3, 0, 0, 0),
            (I.RET, 0, 0, 0, 0),
        ])
        regs, sp, data = blank()
        _, rs, _, _ = run_both(prog, regs, sp, data)
        assert rs[0, 0] == -(2**63)

    def test_wrapping_mul(self):
        prog = I.verify([
            (I.MOVI, 1, 0, 0, 2**40),
            (I.MUL, 2, 1, 1, 0),
            (I.SPS, 2, 0, 0, 0),
            (I.RET, 0, 0, 0, 0),
        ])
        regs, sp, data = blank()
        _, rs, _, _ = run_both(prog, regs, sp, data)
        assert rs[0, 0] == (2**80) % (2**64)  # == 0? no: 2^80 mod 2^64 = 0
        assert rs[0, 0] == 0

    def test_div_min_by_minus_one_wraps(self):
        prog = I.verify([
            (I.MOVI, 1, 0, 0, -(2**63)),
            (I.MOVI, 2, 0, 0, -1),
            (I.DIV, 3, 1, 2, 0),
            (I.SPS, 3, 0, 0, 0),
            (I.RET, 0, 0, 0, 0),
        ])
        regs, sp, data = blank()
        _, rs, _, rst = run_both(prog, regs, sp, data)
        assert rst[0] == I.ST_RETURN
        assert rs[0, 0] == -(2**63)

    def test_shifts(self):
        prog = I.verify([
            (I.MOVI, 1, 0, 0, -1),
            (I.SHR, 2, 1, 0, 1),    # logical: 0x7FFF...
            (I.SHL, 3, 1, 0, 63),   # 0x8000...
            (I.SPS, 2, 0, 0, 0),
            (I.SPS, 3, 0, 0, 1),
            (I.RET, 0, 0, 0, 0),
        ])
        regs, sp, data = blank()
        _, rs, _, _ = run_both(prog, regs, sp, data)
        assert rs[0, 0] == 2**63 - 1
        assert rs[0, 1] == -(2**63)


class TestTraps:
    def test_div_by_zero_traps(self):
        prog = I.verify([
            (I.MOVI, 1, 0, 0, 5),
            (I.MOVI, 2, 0, 0, 0),
            (I.DIV, 3, 1, 2, 0),
            (I.RET, 0, 0, 0, 0),
        ])
        regs, sp, data = blank()
        _, _, _, rst = run_both(prog, regs, sp, data)
        assert rst[0] == I.ST_TRAP

    def test_dynamic_data_oob_traps(self):
        prog = I.verify([
            (I.MOVI, 1, 0, 0, isa.DATA_WORDS),
            (I.LDX, 2, 1, 0, 0),
            (I.RET, 0, 0, 0, 0),
        ])
        regs, sp, data = blank()
        _, _, _, rst = run_both(prog, regs, sp, data)
        assert rst[0] == I.ST_TRAP

    def test_dynamic_negative_index_traps(self):
        prog = I.verify([
            (I.MOVI, 1, 0, 0, -1),
            (I.SPLX, 2, 1, 0, 0),
            (I.RET, 0, 0, 0, 0),
        ])
        regs, sp, data = blank()
        _, _, _, rst = run_both(prog, regs, sp, data)
        assert rst[0] == I.ST_TRAP

    def test_dynamic_store_oob_does_not_write(self):
        prog = I.verify([
            (I.MOVI, 1, 0, 0, 123),
            (I.MOVI, 2, 0, 0, isa.SP_WORDS + 3),
            (I.SPSX, 1, 2, 0, 0),
            (I.RET, 0, 0, 0, 0),
        ])
        regs, sp, data = blank()
        _, rs, _, rst = run_both(prog, regs, sp, data)
        assert rst[0] == I.ST_TRAP
        assert (rs == 0).all()

    def test_explicit_trap(self):
        prog = I.verify([(I.TRAP, 0, 0, 0, 0)])
        regs, sp, data = blank()
        _, _, _, rst = run_both(prog, regs, sp, data)
        assert rst[0] == I.ST_TRAP

    def test_jump_off_end_traps(self):
        # JMP to n (one past the end) lands on TRAP padding.
        prog = I.verify([
            (I.JMP, 0, 0, 0, 2),
            (I.RET, 0, 0, 0, 0),
        ])
        regs, sp, data = blank()
        _, _, _, rst = run_both(prog, regs, sp, data)
        assert rst[0] == I.ST_TRAP


class TestBranches:
    @pytest.mark.parametrize("op,x,y,taken", [
        (I.JEQ, 5, 5, True), (I.JEQ, 5, 6, False),
        (I.JNE, 5, 6, True), (I.JNE, 5, 5, False),
        (I.JLT, -1, 0, True), (I.JLT, 0, 0, False),
        (I.JLE, 0, 0, True), (I.JLE, 1, 0, False),
        (I.JGT, 1, 0, True), (I.JGT, 0, 0, False),
        (I.JGE, 0, 0, True), (I.JGE, -1, 0, False),
    ])
    def test_branch_semantics(self, op, x, y, taken):
        prog = I.verify([
            (I.MOVI, 1, 0, 0, x),      # 0
            (I.MOVI, 2, 0, 0, y),      # 1
            (op, 1, 2, 0, 5),          # 2: taken -> 5
            (I.MOVI, 3, 0, 0, 111),    # 3: fallthrough marker
            (I.JMP, 0, 0, 0, 6),       # 4
            (I.MOVI, 3, 0, 0, 222),    # 5: taken marker
            (I.SPS, 3, 0, 0, 0),       # 6
            (I.RET, 0, 0, 0, 0),       # 7
        ])
        regs, sp, data = blank()
        _, rs, _, _ = run_both(prog, regs, sp, data)
        assert rs[0, 0] == (222 if taken else 111)

    def test_signed_comparison_across_zero(self):
        # -2**63 < anything positive (signed), though huge unsigned.
        prog = I.verify([
            (I.MOVI, 1, 0, 0, -(2**63)),
            (I.MOVI, 2, 0, 0, 1),
            (I.JLT, 1, 2, 0, 5),
            (I.TRAP, 0, 0, 0, 0),
            (I.TRAP, 0, 0, 0, 0),
            (I.RET, 0, 0, 0, 0),
        ])
        regs, sp, data = blank()
        _, _, _, rst = run_both(prog, regs, sp, data)
        assert rst[0] == I.ST_RETURN


class TestIteratorPrograms:
    """Multi-iteration traversal simulated by re-feeding data windows,
    exactly as the memory pipeline does (paper §4.2)."""

    def drive(self, prog, heap, start, sp_init, max_iters=64):
        """heap: dict addr -> list of DATA_WORDS ints (a node image)."""
        regs = np.zeros((1, isa.NREG), dtype=np.int64)
        sp = np.zeros((1, isa.SP_WORDS), dtype=np.int64)
        sp[0, :len(sp_init)] = sp_init
        regs[0, 0] = start
        ops, imm = isa.pack_program(prog)
        iters = 0
        cur = start
        while iters < max_iters:
            iters += 1
            data = np.zeros((1, isa.DATA_WORDS), dtype=np.int64)
            node = heap[cur]
            data[0, :len(node)] = node
            kr, ks, kd, kst = logic_step(ops, imm, regs, sp, data)
            rr, rs, rd, rst = ref_logic_step(prog, regs, sp, data)
            np.testing.assert_array_equal(np.asarray(kr), rr)
            np.testing.assert_array_equal(np.asarray(ks), rs)
            np.testing.assert_array_equal(np.asarray(kst), rst)
            regs, sp = rr.copy(), rs.copy()
            st = int(rst[0])
            if st == I.ST_NEXT_ITER:
                cur = int(regs[0, 0])
                continue
            return st, sp[0], iters
        raise AssertionError("traversal did not terminate")

    def make_list(self, kvs, base=0x1000):
        heap = {}
        addrs = [base + 32 * i for i in range(len(kvs))]
        for i, (k, v) in enumerate(kvs):
            nxt = addrs[i + 1] if i + 1 < len(kvs) else 0
            heap[addrs[i]] = [k, v, nxt]
        return heap, addrs[0]

    def test_list_find_hit(self):
        heap, start = self.make_list([(1, 10), (2, 20), (3, 30)])
        st, sp, iters = self.drive(
            programs.list_find(), heap, start, [2])
        assert st == I.ST_RETURN
        assert sp[programs.SP_RESULT] == 20
        assert iters == 2

    def test_list_find_miss(self):
        heap, start = self.make_list([(1, 10), (2, 20), (3, 30)])
        st, sp, iters = self.drive(
            programs.list_find(), heap, start, [99])
        assert st == I.ST_RETURN
        assert sp[programs.SP_FLAG] == programs.KEY_NOT_FOUND
        assert iters == 3

    def test_list_sum(self):
        heap, start = self.make_list([(i, 10 * i) for i in range(1, 9)])
        st, sp, iters = self.drive(programs.list_sum(), heap, start, [])
        assert st == I.ST_RETURN
        assert sp[programs.SP_ACC] == sum(10 * i for i in range(1, 9))
        assert sp[programs.SP_CNT] == 8
        assert iters == 8

    def make_bst(self, keys, base=0x2000):
        """Build a BST; node = [key, value, left, right]."""
        heap = {}
        nodes = {}

        def alloc(k):
            a = base + 32 * len(nodes)
            nodes[k] = a
            heap[a] = [k, k * 100, 0, 0]
            return a

        root = None
        for k in keys:
            a = alloc(k)
            if root is None:
                root = a
                continue
            cur = root
            while True:
                ck = heap[cur][0]
                if k < ck:
                    if heap[cur][2] == 0:
                        heap[cur][2] = a
                        break
                    cur = heap[cur][2]
                else:
                    if heap[cur][3] == 0:
                        heap[cur][3] = a
                        break
                    cur = heap[cur][3]
        return heap, root

    @pytest.mark.parametrize("needle", [1, 4, 7, 10, 13])
    def test_bst_lower_bound_finds_key(self, needle):
        keys = [8, 4, 12, 2, 6, 10, 14, 1, 3, 5, 7, 9, 11, 13]
        heap, root = self.make_bst(keys)
        st, sp, _ = self.drive(
            programs.bst_lower_bound(), heap, root, [needle])
        assert st == I.ST_RETURN
        node_addr = sp[programs.SP_RESULT]
        assert node_addr != 0
        assert heap[int(node_addr)][0] == needle


class TestBatching:
    def test_lanes_are_independent(self):
        """Divergent lanes (found / not-found / trapped) in one batch."""
        prog = I.verify([
            (I.SPL, 1, 0, 0, 0),
            (I.MOVI, 2, 0, 0, 10),
            (I.DIV, 3, 2, 1, 0),      # traps when sp[0] == 0
            (I.SPS, 3, 0, 0, 1),
            (I.RET, 0, 0, 0, 0),
        ])
        b = 8
        regs = np.zeros((b, isa.NREG), dtype=np.int64)
        sp = np.zeros((b, isa.SP_WORDS), dtype=np.int64)
        data = np.zeros((b, isa.DATA_WORDS), dtype=np.int64)
        sp[:, 0] = [0, 1, 2, 5, 0, 10, -2, 3]
        rr, rs, rd, rst = run_both(prog, regs, sp, data)
        for i, d in enumerate([0, 1, 2, 5, 0, 10, -2, 3]):
            if d == 0:
                assert rst[i] == I.ST_TRAP
            else:
                assert rst[i] == I.ST_RETURN
                assert rs[i, 1] == int(np.trunc(10 / d))

    @pytest.mark.parametrize("b", [1, 2, 32, 256])
    def test_batch_sizes(self, b):
        regs = np.zeros((b, isa.NREG), dtype=np.int64)
        sp = np.zeros((b, isa.SP_WORDS), dtype=np.int64)
        data = np.zeros((b, isa.DATA_WORDS), dtype=np.int64)
        sp[:, 0] = np.arange(b)
        prog = I.verify([
            (I.SPL, 1, 0, 0, 0),
            (I.ADDI, 1, 1, 0, 1000),
            (I.SPS, 1, 0, 0, 1),
            (I.RET, 0, 0, 0, 0),
        ])
        _, rs, _, rst = run_both(prog, regs, sp, data)
        assert (rst == I.ST_RETURN).all()
        np.testing.assert_array_equal(rs[:, 1], np.arange(b) + 1000)
