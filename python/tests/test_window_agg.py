"""window_agg Pallas kernel vs numpy oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import ref_window_agg
from compile.kernels.window_agg import window_agg


def check(values, window, block_windows=64):
    values = np.asarray(values, dtype=np.float32)
    s, mn, mx = window_agg(
        jnp.asarray(values), window=window, block_windows=block_windows)
    rs, rmn, rmx = ref_window_agg(values, window)
    np.testing.assert_allclose(np.asarray(s), rs, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(mn), rmn)
    np.testing.assert_array_equal(np.asarray(mx), rmx)


class TestWindowAgg:
    @pytest.mark.parametrize("n,w", [
        (64, 8), (4096, 64), (4096, 8), (1024, 1024), (128, 2), (64, 64),
    ])
    def test_shapes(self, n, w):
        rng = np.random.default_rng(n * 31 + w)
        check(rng.normal(scale=100.0, size=(n,)), w)

    def test_constant_input(self):
        check(np.full((512,), 3.25), 64)

    def test_negative_values(self):
        check(-np.abs(np.random.default_rng(7).normal(size=(256,))), 8)

    def test_single_window(self):
        v = np.arange(64, dtype=np.float32)
        s, mn, mx = window_agg(jnp.asarray(v), window=64)
        assert float(s[0]) == float(v.sum())
        assert float(mn[0]) == 0.0
        assert float(mx[0]) == 63.0

    def test_block_smaller_than_windows(self):
        check(np.random.default_rng(9).normal(size=(4096,)), 16,
              block_windows=32)

    def test_monotone_ramp_min_max(self):
        v = np.arange(4096, dtype=np.float32)
        s, mn, mx = window_agg(jnp.asarray(v), window=64)
        np.testing.assert_array_equal(
            np.asarray(mn), v.reshape(64, 64)[:, 0])
        np.testing.assert_array_equal(
            np.asarray(mx), v.reshape(64, 64)[:, -1])

    def test_pmu_like_signal(self):
        """µPMU-like: 120 Hz sinusoid + noise, windows of 1 s (120
        samples won't divide; use the packed 64-leaf layout as the app
        does)."""
        rng = np.random.default_rng(42)
        t = np.arange(4096, dtype=np.float32)
        v = 120.0 * np.sin(2 * np.pi * t / 120.0) + rng.normal(
            scale=0.5, size=(4096,)).astype(np.float32)
        check(v, 64)
