"""Verifier + encoding unit tests for the Python ISA mirror."""

import numpy as np
import pytest

from compile.kernels import isa, programs

I = isa


class TestVerify:
    def test_accepts_all_sample_programs(self):
        for name, fn in programs.ALL.items():
            prog = fn()
            assert isa.verify(prog) is prog, name

    def test_rejects_empty(self):
        with pytest.raises(ValueError, match="empty"):
            isa.verify([])

    def test_rejects_too_long(self):
        prog = [(I.NOP, 0, 0, 0, 0)] * (isa.MAX_INSTRS) + [
            (I.RET, 0, 0, 0, 0)]
        with pytest.raises(ValueError, match="too long"):
            isa.verify(prog)

    def test_rejects_bad_opcode(self):
        with pytest.raises(ValueError, match="bad opcode"):
            isa.verify([(99, 0, 0, 0, 0), (I.RET, 0, 0, 0, 0)])

    def test_rejects_register_out_of_range(self):
        with pytest.raises(ValueError, match="register"):
            isa.verify([(I.MOVI, 16, 0, 0, 1), (I.RET, 0, 0, 0, 0)])

    def test_rejects_backward_jump(self):
        prog = [
            (I.NOP, 0, 0, 0, 0),
            (I.JMP, 0, 0, 0, 0),  # backward
            (I.RET, 0, 0, 0, 0),
        ]
        with pytest.raises(ValueError, match="forward"):
            isa.verify(prog)

    def test_rejects_self_jump(self):
        prog = [(I.JMP, 0, 0, 0, 0), (I.RET, 0, 0, 0, 0)]
        with pytest.raises(ValueError, match="forward"):
            isa.verify(prog)

    def test_rejects_jump_past_end(self):
        prog = [(I.JMP, 0, 0, 0, 5), (I.RET, 0, 0, 0, 0)]
        with pytest.raises(ValueError, match="forward"):
            isa.verify(prog)

    def test_jump_to_one_past_end_allowed(self):
        # Target == n is the "fall off the end" slot; the interpreter
        # traps there, and the verifier permits it (it is still forward).
        prog = [(I.JMP, 0, 0, 0, 2), (I.RET, 0, 0, 0, 0)]
        isa.verify(prog)

    def test_rejects_static_data_offset_oob(self):
        prog = [(I.LDD, 1, 0, 0, isa.DATA_WORDS), (I.RET, 0, 0, 0, 0)]
        with pytest.raises(ValueError, match="data offset"):
            isa.verify(prog)

    def test_rejects_static_sp_offset_oob(self):
        prog = [(I.SPS, 1, 0, 0, isa.SP_WORDS), (I.RET, 0, 0, 0, 0)]
        with pytest.raises(ValueError, match="sp offset"):
            isa.verify(prog)

    def test_rejects_nonterminal_tail(self):
        prog = [(I.MOVI, 1, 0, 0, 1), (I.NOP, 0, 0, 0, 0)]
        with pytest.raises(ValueError, match="NEXT/RET/TRAP"):
            isa.verify(prog)


class TestPack:
    def test_pads_with_trap(self):
        prog = programs.list_find()
        ops, imm = isa.pack_program(prog)
        assert ops.shape == (isa.MAX_INSTRS, 4)
        assert imm.shape == (isa.MAX_INSTRS,)
        assert (ops[len(prog):, 0] == I.TRAP).all()

    def test_preserves_fields(self):
        prog = [(I.ADDI, 3, 4, 0, -17), (I.RET, 0, 0, 0, 0)]
        ops, imm = isa.pack_program(prog)
        assert tuple(ops[0]) == (I.ADDI, 3, 4, 0)
        assert imm[0] == -17

    def test_negative_imm_round_trips(self):
        prog = [(I.MOVI, 1, 0, 0, -(2**63)), (I.RET, 0, 0, 0, 0)]
        _, imm = isa.pack_program(prog)
        assert imm[0] == np.int64(-(2**63))
