"""Property-based sweeps: random verified programs and random workspaces
must agree bit-for-bit between the Pallas kernel and the exact oracle,
and window_agg must agree across shapes/dtypes ranges (paper-required
invariant: the accelerator is a faithful executor of the ISA)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import isa
from compile.kernels.logic_step import logic_step
from compile.kernels.ref import ref_logic_step, ref_window_agg
from compile.kernels.window_agg import window_agg

I = isa

imm64 = st.integers(min_value=-(2**63), max_value=2**63 - 1)
reg = st.integers(min_value=0, max_value=isa.NREG - 1)


@st.composite
def verified_program(draw, max_len=24):
    """Generate a random program that passes the verifier."""
    n = draw(st.integers(min_value=1, max_value=max_len))
    prog = []
    for pc in range(n - 1):
        kind = draw(st.sampled_from([
            "alu", "alu", "mem", "jump", "movi", "terminal_maybe"]))
        if kind == "terminal_maybe" and draw(st.booleans()):
            prog.append((draw(st.sampled_from([I.NEXT, I.RET])), 0, 0, 0, 0))
            continue
        if kind == "alu":
            op = draw(st.sampled_from(
                [I.ADD, I.SUB, I.MUL, I.DIV, I.AND, I.OR, I.XOR, I.MOV,
                 I.NOT, I.SHL, I.SHR, I.ADDI]))
            prog.append((op, draw(reg), draw(reg), draw(reg),
                         draw(st.integers(0, 63)) if op in (I.SHL, I.SHR)
                         else draw(st.integers(-1000, 1000))))
        elif kind == "movi":
            prog.append((I.MOVI, draw(reg), 0, 0, draw(imm64)))
        elif kind == "mem":
            op = draw(st.sampled_from(
                [I.LDD, I.STD, I.SPL, I.SPS, I.LDX, I.STX, I.SPLX,
                 I.SPSX]))
            window = (isa.DATA_WORDS if op in (I.LDD, I.STD, I.LDX, I.STX)
                      else isa.SP_WORDS)
            if op in (I.LDD, I.STD, I.SPL, I.SPS):
                off = draw(st.integers(0, window - 1))
            else:
                # dynamic: allow (rare) OOB to exercise trap parity
                off = draw(st.integers(-2, window + 1))
            prog.append((op, draw(reg), draw(reg), 0, off))
        else:  # jump
            op = draw(st.sampled_from(
                [I.JEQ, I.JNE, I.JLT, I.JLE, I.JGT, I.JGE, I.JMP]))
            target = draw(st.integers(pc + 1, n))
            prog.append((op, draw(reg), draw(reg), 0, target))
    prog.append((draw(st.sampled_from([I.NEXT, I.RET, I.TRAP])), 0, 0, 0, 0))
    return isa.verify(prog)


def random_ws(rng, b):
    return (
        rng.integers(-2**62, 2**62, size=(b, isa.NREG), dtype=np.int64),
        rng.integers(-2**62, 2**62, size=(b, isa.SP_WORDS), dtype=np.int64),
        rng.integers(-2**62, 2**62, size=(b, isa.DATA_WORDS),
                     dtype=np.int64),
    )


@settings(max_examples=60, deadline=None)
@given(prog=verified_program(), seed=st.integers(0, 2**32 - 1),
       b=st.sampled_from([1, 3, 8]))
def test_logic_step_matches_oracle(prog, seed, b):
    rng = np.random.default_rng(seed)
    regs, sp, data = random_ws(rng, b)
    ops, imm = isa.pack_program(prog)
    kr, ks, kd, kst = logic_step(ops, imm, regs, sp, data)
    rr, rs, rd, rst = ref_logic_step(prog, regs, sp, data)
    np.testing.assert_array_equal(np.asarray(kst), rst)
    np.testing.assert_array_equal(np.asarray(kr), rr)
    np.testing.assert_array_equal(np.asarray(ks), rs)
    np.testing.assert_array_equal(np.asarray(kd), rd)


@settings(max_examples=25, deadline=None)
@given(
    n_windows=st.sampled_from([1, 2, 8, 64]),
    w=st.sampled_from([2, 8, 64, 128]),
    seed=st.integers(0, 2**32 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e4]),
)
def test_window_agg_matches_oracle(n_windows, w, seed, scale):
    rng = np.random.default_rng(seed)
    v = rng.normal(scale=scale, size=(n_windows * w,)).astype(np.float32)
    import jax.numpy as jnp
    s, mn, mx = window_agg(
        jnp.asarray(v), window=w,
        block_windows=min(64, n_windows))
    rs, rmn, rmx = ref_window_agg(v, w)
    np.testing.assert_allclose(
        np.asarray(s), rs, rtol=1e-4, atol=1e-4 * scale)
    np.testing.assert_array_equal(np.asarray(mn), rmn)
    np.testing.assert_array_equal(np.asarray(mx), rmx)
