import jax

# The PULSE ISA is 64-bit: enable x64 before any kernel import traces.
jax.config.update("jax_enable_x64", True)
