"""L2 model wrappers + AOT lowering sanity."""

import numpy as np
import pytest

from compile import aot, model
from compile.kernels import isa, programs


class TestModel:
    def test_logic_batch_step_next_ptr(self):
        prog = programs.list_find()
        ops, imm = isa.pack_program(prog)
        b = 4
        regs = np.zeros((b, isa.NREG), dtype=np.int64)
        sp = np.zeros((b, isa.SP_WORDS), dtype=np.int64)
        data = np.zeros((b, isa.DATA_WORDS), dtype=np.int64)
        sp[:, 0] = 42  # search key, will not match
        data[:, 0] = 7  # node.key
        data[:, 2] = 0xBEEF0  # node.next
        r, s, d, st, nxt = model.logic_batch_step(ops, imm, regs, sp, data)
        assert (np.asarray(st) == isa.ST_NEXT_ITER).all()
        assert (np.asarray(nxt) == 0xBEEF0).all()
        np.testing.assert_array_equal(np.asarray(nxt), np.asarray(r)[:, 0])

    def test_window_aggregate_mean(self):
        v = np.arange(256, dtype=np.float32)
        s, mean, mn, mx = model.window_aggregate(v, window=64)
        np.testing.assert_allclose(
            np.asarray(mean), v.reshape(4, 64).mean(axis=1), rtol=1e-6)


class TestAOT:
    def test_lower_logic_produces_hlo_text(self):
        text = aot.lower_logic(32)
        assert "HloModule" in text
        assert "ENTRY" in text
        # 5 outputs: regs, sp, data, status, next_ptr
        assert "s64[32,16]" in text

    def test_lower_window_produces_hlo_text(self):
        text = aot.lower_window(4096, 64)
        assert "HloModule" in text
        assert "f32[64]" in text

    def test_lowered_text_is_parseable_back(self):
        """Round-trip through the XLA HLO text parser — the exact path
        the Rust runtime uses (HloModuleProto::from_text)."""
        from jax._src.lib import xla_client as xc
        text = aot.lower_window(4096, 64)
        # The python client exposes the parser through
        # XlaComputation(text)-equivalent: re-parse via
        # hlo_module_from_text if available; otherwise assert structure.
        parse = getattr(xc._xla, "hlo_module_from_text", None)
        if parse is None:
            pytest.skip("hlo_module_from_text not exposed in this jaxlib")
        mod = parse(text)
        assert mod is not None

    def test_batch_shapes_differ(self):
        t32 = aot.lower_logic(32)
        t256 = aot.lower_logic(256)
        assert "s64[32,16]" in t32
        assert "s64[256,16]" in t256
